"""FedAvg as a deployable fleet workload: compression error-feedback
exactness, the round-timeout failure shape, identity-derived non-IID
shifts (churn-stable), mixed compressed/plain rounds, per-arm loss
traces through the shard merge, and the live optimizer A/B."""
import numpy as np
import pytest

from fault_fabric import FaultPlan, FaultyTransport
from repro.core.assignment import Status
from repro.core.consistency import TaggedResult
from repro.core.fleet import Fleet
from repro.core.rollout import ArmStats, arm_report, merge_arm_reports
from repro.fed.fedavg import (
    DIM,
    FEDERATED_ROUND_SOURCE,
    FederatedRoundError,
    FederatedSession,
    _features,
    client_shift,
    default_client_update,
)


def _wrap(plan):
    return lambda inner: FaultyTransport(inner, plan)


# ---------------------------------------------------------------------------
# Satellite: error feedback must match what the cloud reconstructs
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["int8_ef", "topk_ef"])
def test_error_feedback_residual_matches_decoded_payload(kind):
    """The EF invariant, exactly: residual == w - decode(encode(w)) for
    both payload kinds. topk_ef ships float32 values, so a residual
    computed against the float64 kept values (the old bug) diverges from
    the cloud's reconstruction by the float32 rounding error."""
    class App:
        client_id = "c000"
        fed_state = {}

    w = np.random.default_rng(5).normal(size=DIM)
    p = FederatedSession._compress_payload(App, w, kind, 0.5)
    back = FederatedSession.decode_payload(p)
    np.testing.assert_array_equal(App.fed_state["residual"], w - back)
    # and across rounds: round 2 encodes w + residual, same invariant
    carried = App.fed_state["residual"].copy()
    p2 = FederatedSession._compress_payload(App, w, kind, 0.5)
    back2 = FederatedSession.decode_payload(p2)
    np.testing.assert_array_equal(App.fed_state["residual"],
                                  (w + carried) - back2)


def test_topk_payload_size_is_deterministic_under_ties():
    """Exactly ``max(1, int(n * frac))`` values ship, even when
    magnitudes tie at the threshold (the old jnp mask kept every
    coordinate >= the k-th magnitude, inflating tied payloads), and the
    EF residual still matches the reconstruction exactly."""
    class App:
        client_id = "c000"
        fed_state = {}

    w = np.array([1.0, -1.0, 1.0, -1.0, 0.5, 0.25, -0.125, 0.0625])
    p = FederatedSession._compress_payload(App, w, "topk_ef", 0.25)
    assert len(p["idx"]) == max(1, int(DIM * 0.25))
    np.testing.assert_array_equal(
        App.fed_state["residual"], w - FederatedSession.decode_payload(p))


# ---------------------------------------------------------------------------
# Satellite: a starved round fails with a named error, not a bare unpack
# ---------------------------------------------------------------------------


def test_round_timeout_raises_federated_round_error():
    plan = FaultPlan()
    fleet = Fleet.create(4, seed=7, transport_wrap=_wrap(plan))
    try:
        sess = FederatedSession(fleet, seed=3, round_timeout_s=1.0)
        fe = fleet.frontend(sess.user_id)
        # deploy first — module installs ack over task_done frames too
        sess.ensure_round_module(fe)
        plan.delay(tag="task_done")          # park every round result
        with pytest.raises(FederatedRoundError,
                           match="federated round 0 failed"):
            sess.run_rounds(fe, 1)
    finally:
        plan.release()
        fleet.shutdown()


# ---------------------------------------------------------------------------
# Satellite: the non-IID shift follows client identity, not enumeration
# ---------------------------------------------------------------------------


def test_client_shift_is_pure_and_bounded():
    ids = [f"c{i:03d}" for i in range(100)]
    assert [client_shift(c) for c in ids] == [client_shift(c) for c in ids]
    assert all(0.0 <= client_shift(c) < 0.36 for c in ids)
    assert len({client_shift(c) for c in ids[:16]}) > 2   # actually non-IID


def _one_client_round(n_clients: int, cid: str, seed: int = 3):
    """Run one federated round on ``cid`` alone in a fleet of
    ``n_clients`` and return (window, payload)."""
    fleet = Fleet.create(n_clients, seed=7)
    try:
        sess = FederatedSession(fleet, seed=seed)
        fe = fleet.frontend(sess.user_id)
        sess.ensure_round_module(fe)
        xs = np.array(fleet.client_apps[cid].data[:64])
        handle = fe.submit_analytics(
            "federated_round", iterations=1, client_ids=[cid],
            params=sess._round_params(sess.w, None, 0.25, False))
        it = sess._commit_round(handle, 0)
        assert it.n_accepted == 1
        return xs, np.asarray(it.value[0], dtype=np.float64), sess.true_w
    finally:
        fleet.shutdown()


def test_shift_follows_identity_across_fleet_compositions():
    """A churned/re-homed client keeps its data distribution: the same
    client id produces the same round update no matter how the rest of
    the fleet is composed, and the update matches the identity-derived
    shift (under the old insertion-order scheme c002's shift was
    0.1 * idx — position-dependent, 0.2 here)."""
    xs4, got4, true_w = _one_client_round(4, "c002")
    xs3, got3, _ = _one_client_round(3, "c002")
    np.testing.assert_array_equal(xs4, xs3)       # same telemetry stream
    np.testing.assert_array_equal(got4, got3)     # same distribution
    ys = _features(xs4) @ true_w + client_shift("c002")
    expected = default_client_update(np.zeros(DIM), xs4, ys)
    np.testing.assert_allclose(got4, expected, rtol=1e-12)
    assert client_shift("c002") != pytest.approx(0.2)


# ---------------------------------------------------------------------------
# Satellite: mixed plain/compressed payloads in one round
# ---------------------------------------------------------------------------


def test_aggregate_value_decodes_per_element():
    sess = FederatedSession(None, seed=0)
    plain = [float(i) for i in range(DIM)]
    comp = {"kind": "topk_ef", "dim": DIM, "idx": [0], "val": [2.0]}
    w = sess._aggregate_value([comp, plain])
    expected = np.stack([
        np.array([2.0] + [0.0] * (DIM - 1)),
        np.arange(DIM, dtype=np.float64),
    ]).mean(axis=0)
    np.testing.assert_allclose(w, expected)


def test_mixed_compression_round_after_module_swap():
    """A mid-session swap of the round driver that changes the payload
    shape (plain lists vs compressed dicts) must not break aggregation:
    payloads are decoded per element, and — both drivers tagging the
    same optimizer rule — nothing is dropped."""
    plain_variant = FEDERATED_ROUND_SOURCE.replace(
        'comp = p.get("compression")', "comp = None")
    assert plain_variant != FEDERATED_ROUND_SOURCE
    fleet = Fleet.create(4, seed=7)
    try:
        sess = FederatedSession(fleet, seed=3)
        fe = fleet.frontend(sess.user_id)
        sess.ensure_round_module(fe)
        dep = fe.deploy_code("federated_round", plain_variant,
                             client_ids=["c000", "c001"])
        dep.result(timeout=15.0)
        handle = fe.submit_analytics(
            "federated_round", iterations=1,
            params=sess._round_params(sess.w, "int8_ef", 0.25, False))
        it = sess._commit_round(handle, 0)
        assert it.n_accepted == 4 and it.n_dropped == 0
        kinds = {type(v).__name__ for v in it.value}
        assert kinds == {"list", "dict"}          # genuinely mixed
        w = sess._aggregate_value(it.value)
        assert w.shape == (DIM,) and np.all(np.isfinite(w))
    finally:
        fleet.shutdown()


# ---------------------------------------------------------------------------
# Context-aware active modules (the mechanism the round driver rides)
# ---------------------------------------------------------------------------


CTX_MODULE = """
import numpy as np

def run(xs, ctx):
    st = ctx["state"]
    st["calls"] = st.get("calls", 0) + 1
    return {"__tagged__": True, "code_md5": "rule-md5",
            "payload": [float(len(xs))], "metric": 0.5}
"""


def test_ctx_module_state_and_tagged_envelope():
    fleet = Fleet.create(2, seed=0)
    try:
        fe = fleet.frontend("u")
        fe.deploy_code("ctxmod", CTX_MODULE).result(timeout=15.0)
        handle = fe.submit_analytics(
            "ctxmod", iterations=2,
            params={"arms": {"c000": "A", "c001": "B"}})
        results, done = handle.result(timeout=15.0)
        assert done.status is Status.DONE
        assert len(results) == 2
        for it in results:
            # the envelope's md5 wins (the rule, not the driver module)
            assert it.winning_md5 == "rule-md5"
            a = ArmStats.from_report(it.arm_stats["A"])
            assert a.metric_n == 1 and a.metric_mean == 0.5
        # per-method state persisted across iterations
        assert fleet.client_apps["c000"].method_state["ctxmod"]["calls"] == 2
    finally:
        fleet.shutdown()


# ---------------------------------------------------------------------------
# Arm metrics: wire shape, accumulation, exact shard merge
# ---------------------------------------------------------------------------


def test_tagged_result_metric_wire_roundtrip():
    r = TaggedResult("c0", 1, "md", payload=[1.0], arm="A", metric=0.25)
    d = r.to_wire_dict()
    assert d["metric"] == 0.25
    assert TaggedResult.from_wire_dict(d).metric == 0.25
    bare = TaggedResult("c0", 1, "md")
    assert "metric" not in bare.to_wire_dict()
    assert TaggedResult.from_wire_dict(bare.to_wire_dict()).metric is None


def test_arm_report_accumulates_metrics_and_merges():
    rs = [TaggedResult("c0", 0, "m", payload=[0.0], arm="A", metric=1.0),
          TaggedResult("c1", 0, "m", payload=[0.0], arm="A", metric=3.0),
          TaggedResult("c2", 0, "m", payload=[0.0], arm="B"),
          TaggedResult("c3", 0, "error:boom", arm="B", metric=9.0)]
    rep = arm_report(rs, {})
    a = ArmStats.from_report(rep["A"])
    assert (a.metric_sum, a.metric_n, a.metric_mean) == (4.0, 2, 2.0)
    b = ArmStats.from_report(rep["B"])
    assert b.metric_n == 0 and b.metric_mean is None  # errors don't count
    merged = merge_arm_reports([rep, rep])
    assert ArmStats.from_report(merged["A"]).metric_sum == 8.0
    assert ArmStats.from_report(merged["A"]).metric_n == 4
    # pre-metric reports (older shard legs) still merge
    legacy = {"A": {"n": 1, "errors": 0, "value_sum": 0.5, "value_n": 1}}
    m2 = merge_arm_reports([rep, legacy])
    assert m2["A"]["metric_n"] == 2 and m2["A"]["n"] == 3


# ---------------------------------------------------------------------------
# Tentpole: live optimizer A/B over a sharded fleet, loss traces intact
# ---------------------------------------------------------------------------


def test_run_ab_hot_swap_with_loss_traces_sharded():
    fleet = Fleet.create(8, seed=7, shards=2)
    try:
        sess = FederatedSession(fleet, seed=3)
        fe = fleet.frontend(sess.user_id)
        log = sess.run_ab(fe, n_rounds=6, swap_round=3)
        by_arm = {}
        for row in log:
            by_arm.setdefault(row["arm"], []).append(row)
        assert sorted(by_arm) == ["A", "B"]
        for arm, rows in by_arm.items():
            assert [r["round"] for r in rows] == list(range(6))
            assert all(r["loss"] is not None for r in rows)
            assert all(r["n_dropped"] == 0 for r in rows)
            assert all(r["n_accepted"] == 4 for r in rows)
        a_md5s = [r["winning_md5"] for r in by_arm["A"]]
        b_md5s = [r["winning_md5"] for r in by_arm["B"]]
        assert len(set(a_md5s)) == 1                   # A never swapped
        assert len(set(b_md5s[:3])) == 1 == len(set(b_md5s[3:]))
        assert b_md5s[0] == a_md5s[0] != b_md5s[-1]    # B swapped at 3
        # convergence trace actually descends for both arms
        for rows in by_arm.values():
            assert rows[-1]["err"] < rows[0]["err"]
    finally:
        fleet.shutdown()


def test_cloud_aggregate_slot_runs_on_cloud_path():
    fleet = Fleet.create(4, seed=7)
    try:
        sess = FederatedSession(fleet, seed=3)
        fe = fleet.frontend(sess.user_id)
        sess.run_rounds(fe, 2, cloud_aggregate=True)
        assert [r["n_accepted"] for r in sess.round_log] == [4, 4]
        assert fleet.cloud_app.registry.resolve(
            sess.user_id, "fed_aggregate") is not None
        with pytest.raises(ValueError, match="cloud_aggregate"):
            sess.run_rounds(fe, 1, compression="int8_ef",
                            cloud_aggregate=True)
    finally:
        fleet.shutdown()
