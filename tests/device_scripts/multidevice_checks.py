"""Multi-device correctness checks, run as a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (tests/conftest keeps
the main pytest process at 1 device per the dry-run contract).

Exits 0 iff every check passes; prints one line per check.
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses

import jax
import jax.numpy as jnp
import jax.random as jr
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..",
                                "src"))

from repro.configs import get_config, make_run_config
from repro.models import build_model, moe
from repro.models.blocks import ModelCtx
from repro.optim.compression import compressed_psum
from repro.sharding.auto import run_rules, shardings_for
from repro.launch.specs import param_shardings

FAILURES = []


def check(name, ok):
    print(("PASS" if ok else "FAIL"), name, flush=True)
    if not ok:
        FAILURES.append(name)


def moe_ep_multidevice():
    cfg = dataclasses.replace(get_config("dbrx-132b").reduced(),
                              capacity_factor=8.0)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    p = moe.moe_init(jr.PRNGKey(0), cfg, jnp.float32)
    x = jr.normal(jr.PRNGKey(1), (4, 16, cfg.d_model))
    y_d, _ = jax.jit(lambda p, x: moe.moe_apply_dense(p, x, cfg))(p, x)
    with jax.set_mesh(mesh):
        y_e, _ = jax.jit(lambda p, x: moe.moe_apply_ep(p, x, cfg, mesh))(p, x)
    check("moe_ep_8dev_fwd", float(jnp.abs(y_e - y_d).max()) < 1e-5)

    def loss(p, x):
        y, aux = moe.moe_apply_ep(p, x, cfg, mesh)
        return (y ** 2).mean() + 0.01 * aux

    with jax.set_mesh(mesh):
        g = jax.jit(jax.grad(loss))(p, x)
    ok = all(bool(jnp.isfinite(v).all()) for v in jax.tree.leaves(g))
    check("moe_ep_8dev_grad_finite", ok)


def seqshard_decode_multidevice():
    for name in ("qwen3-0.6b", "hymba-1.5b"):
        cfg = get_config(name).reduced()
        m = build_model(cfg)
        p = m.init(jr.PRNGKey(0))
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        ctx_d = ModelCtx(attn_impl="blockwise", decode_attn_impl="dense",
                         moe_impl="dense", remat_policy="none")
        ctx_s = ModelCtx(mesh=mesh, attn_impl="blockwise",
                         decode_attn_impl="seqshard", moe_impl="dense",
                         remat_policy="none", tp_axis="model")
        toks = jr.randint(jr.PRNGKey(1), (2, 32), 0, cfg.vocab_size)
        cache = m.init_cache(2, 64, ctx_d)
        lg, cache1, pos = jax.jit(
            lambda p, t, c: m.prefill(p, t, c, ctx_d))(p, toks, cache)
        t0 = jnp.argmax(lg, -1).astype(jnp.int32)
        lg_d, _ = jax.jit(lambda p, t, c, q: m.decode_step(
            p, t, c, q, ctx_d))(p, t0, cache1, pos)
        kv = NamedSharding(mesh, P(None, None, None, "model", None))
        cache_s = jax.tree.map(
            lambda a: jax.device_put(a, kv)
            if a.ndim == 5 and a.shape[3] >= 8 else a, cache1)
        with jax.set_mesh(mesh):
            lg_s, _ = jax.jit(lambda p, t, c, q: m.decode_step(
                p, t, c, q, ctx_s))(p, t0, cache_s, pos)
        check(f"seqshard_decode_{name}",
              float(jnp.abs(lg_s - lg_d).max()) < 5e-5)


def compressed_psum_multidevice():
    mesh = jax.make_mesh((8,), ("data",))
    g = {"w": jr.normal(jr.PRNGKey(2), (8, 64))}
    gs = jax.device_put(g, NamedSharding(mesh, P("data", None)))
    with jax.set_mesh(mesh):
        out = jax.jit(
            lambda t: compressed_psum(t, mesh, ("data",),
                                      spec_fn=lambda l: P("data", None))
        )(gs)
    # each rank held one row; psum-mean across ranks => row-mean bcast
    want = np.asarray(g["w"]).mean(axis=0)
    got = np.asarray(out["w"][0])
    rel = np.abs(got - want).max() / (np.abs(want).max() + 1e-9)
    check("compressed_psum_int8", rel < 0.02)   # int8 quantization error


def sharded_train_step_multidevice():
    """The full HotSwap train step under pjit on a 4x2 mesh, vs the
    single-device result: losses must match closely."""
    from repro.optim.api import build_optimizer
    from repro.train import HotSwapTrainStep, init_state
    from repro.core.registry import ActiveCodeRegistry
    from repro.data.synthetic import batch_at, make_task
    from repro.launch.specs import abstract_state, state_shardings

    run = make_run_config("smollm-135m", "train_4k")
    run = dataclasses.replace(
        run, model=run.model.reduced(),
        shape=dataclasses.replace(run.shape, seq_len=64, global_batch=8),
        train=dataclasses.replace(run.train, learning_rate=1e-3,
                                  warmup_steps=2, total_steps=20))
    task = make_task(run.model.vocab_size, 64, 8, seed=0)

    losses = {}
    for tag, mesh in (("1dev", None),
                      ("4x2", jax.make_mesh((4, 2), ("data", "model")))):
        model = build_model(run.model)
        opt = build_optimizer(run.train, run.model.param_dtype)
        state = init_state(model, opt, jr.PRNGKey(0), run)
        reg = ActiveCodeRegistry()
        bindings = {s: reg.bind("u", s) for s in
                    ("train_loss", "train_metrics", "grad_transform")}
        if mesh is None:
            step = HotSwapTrainStep(model, run, opt, bindings)
            ls = []
            for i in range(3):
                state, m = step(state, batch_at(task, i))
                ls.append(float(m["loss"]))
        else:
            rules = run_rules(run)
            with jax.set_mesh(mesh):
                step = HotSwapTrainStep(model, run, opt, bindings,
                                        mesh=mesh, rules=rules)
                ls = []
                for i in range(3):
                    state, m = step(state, batch_at(task, i))
                    ls.append(float(m["loss"]))
        losses[tag] = ls
    diff = max(abs(a - b) for a, b in zip(losses["1dev"], losses["4x2"]))
    check("sharded_train_step_matches", diff < 1e-3)


def elastic_reshard_roundtrip():
    """Checkpoint written unsharded, restored onto a 2x4 mesh with
    param shardings (elastic reshard-on-load)."""
    import tempfile
    from repro.checkpoint.store import restore_tree, save_tree

    cfg = get_config("qwen3-0.6b").reduced()
    model = build_model(cfg)
    p = model.init(jr.PRNGKey(0))
    with tempfile.TemporaryDirectory() as td:
        path = save_tree(td, p, step=1)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        run = make_run_config("qwen3-0.6b", "train_4k")
        run = dataclasses.replace(run, model=cfg)
        rules = run_rules(run)
        p_sds = jax.eval_shape(model.init,
                               jax.ShapeDtypeStruct((2,), jnp.uint32))
        shd = param_shardings(model, p_sds, rules, mesh)
        got = restore_tree(path, p, shardings=shd)
    same = all(np.array_equal(np.asarray(a, np.float32),
                              np.asarray(b, np.float32))
               for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(got)))
    sharded = any(len(x.sharding.device_set) > 1
                  for x in jax.tree.leaves(got))
    check("elastic_reshard_values", same)
    check("elastic_reshard_sharded", sharded)


if __name__ == "__main__":
    assert jax.device_count() == 8, jax.device_count()
    moe_ep_multidevice()
    seqshard_decode_multidevice()
    compressed_psum_multidevice()
    sharded_train_step_multidevice()
    elastic_reshard_roundtrip()
    print("FAILURES:", FAILURES, flush=True)
    sys.exit(1 if FAILURES else 0)
