"""The unified assignment-handle control plane: typed event streams,
cancellation, versioned deployments with rollback, and the cloud node's
concurrent-assignment backpressure gate."""
import time

import pytest

from repro.core import (
    DeployEvent,
    DoneEvent,
    IterationEvent,
    Status,
    Target,
    event_from_wire,
)
from repro.core.fleet import AssignmentHandle, Deployment, Fleet
from repro.core.registry import ActiveCodeRegistry

MEAN_X2 = """
import jax.numpy as jnp
def run(xs):
    return jnp.mean(xs) * 2.0
"""

MEAN_X4 = """
import jax.numpy as jnp
def run(xs):
    return jnp.mean(xs) * 4.0
"""


@pytest.fixture()
def fleet():
    f = Fleet.create(4, seed=7)
    yield f
    f.shutdown()


# ---------------------------------------------------------------------------
# Typed events on the wire
# ---------------------------------------------------------------------------


def test_every_event_type_round_trips_through_wire_codec():
    events = [
        IterationEvent("asg-1", 3, [1.5, 2.0], "abcd1234", 4, 1, 0),
        IterationEvent("asg-2", 0, 7.25, None, 2, 0, 2),
        DeployEvent("asg-3", "my_slot", "ff00" * 8, 2, Target.CLIENTS, 4, 4),
        DeployEvent("asg-4", "agg", "00ff" * 8, 1, Target.CLOUD, 1, 1),
        DoneEvent("asg-5", Status.DONE, "ok"),
        DoneEvent("asg-6", Status.CANCELLED, "cancelled during iteration 9"),
        DoneEvent("asg-7", Status.FAILED, "handler crash"),
    ]
    for ev in events:
        back = event_from_wire(ev.to_wire())
        assert back == ev
        assert type(back) is type(ev)


def test_unknown_event_tag_rejected():
    with pytest.raises(ValueError, match="unknown event"):
        event_from_wire(b'{"event": "bogus"}')


def test_stream_events_are_wire_round_tripped_instances(fleet):
    """What arrives on a handle's stream went through bytes: enums come
    back as enums, payloads as plain JSON types."""
    fe = fleet.frontend("u1")
    handle = fe.submit_analytics("mean", iterations=1,
                                 params={"n_values": 8})
    results, done = handle.result()
    assert isinstance(results[0], IterationEvent)
    assert isinstance(results[0].value, list)
    assert isinstance(done, DoneEvent)
    assert done.status is Status.DONE


# ---------------------------------------------------------------------------
# Handle surface
# ---------------------------------------------------------------------------


def test_handle_status_lifecycle(fleet):
    fe = fleet.frontend("u1")
    handle = fe.submit_analytics("mean", iterations=2,
                                 params={"n_values": 8})
    assert isinstance(handle, AssignmentHandle)
    results, done = handle.result()
    assert handle.status == Status.DONE
    assert handle.done
    assert len(results) == 2
    assert [e.iteration for e in results] == [0, 1]


def test_events_iterator_survives_concurrent_draining(fleet):
    """A live events() iterator must deliver events that other handle
    methods (status polls, result()) drained into history between its
    yields — no event is lost to mixed-style consumption."""
    fe = fleet.frontend("u1")
    handle = fe.submit_analytics("mean", iterations=4,
                                 params={"n_values": 8})
    stream = handle.events()
    first = next(stream)
    assert first.iteration == 0
    handle.result()                 # drains everything behind the iterator
    rest = list(stream)
    iters = [e for e in rest if isinstance(e, IterationEvent)]
    assert [e.iteration for e in iters] == [1, 2, 3]
    assert isinstance(rest[-1], DoneEvent)


def test_events_replay_after_result(fleet):
    """A drained handle can be iterated again: history is replayed."""
    fe = fleet.frontend("u1")
    handle = fe.submit_analytics("mean", iterations=3,
                                 params={"n_values": 8})
    handle.result()
    evs = list(handle.events())
    assert len([e for e in evs if isinstance(e, IterationEvent)]) == 3
    assert isinstance(evs[-1], DoneEvent)


def test_cancel_stops_100_iteration_assignment_early(fleet):
    """The acceptance scenario: a 100-iteration assignment is cancelled
    after a few commits; it stops cleanly mid-iteration instead of
    running out the remaining ~95 iterations."""
    fe = fleet.frontend("u1")
    handle = fe.submit_analytics("mean", iterations=100,
                                 params={"n_values": 8})
    stream = handle.events()
    seen = [next(stream) for _ in range(3)]      # let a few iterations commit
    handle.cancel()
    results, done = handle.result(timeout=10.0)
    assert done.status == Status.CANCELLED
    assert "cancelled during iteration" in done.detail
    assert 3 <= len(results) < 100
    assert handle.status == Status.CANCELLED
    assert all(isinstance(e, IterationEvent) for e in seen)


def test_cancel_already_done_assignment_is_noop(fleet):
    fe = fleet.frontend("u1")
    handle = fe.submit_analytics("mean", iterations=1,
                                 params={"n_values": 8})
    results, done = handle.result()
    handle.cancel()                               # handler long gone
    time.sleep(0.05)
    assert handle.status == Status.DONE
    assert len(results) == 1


# ---------------------------------------------------------------------------
# Versioned deployments + rollback
# ---------------------------------------------------------------------------


def test_deploy_emits_typed_deploy_event(fleet):
    fe = fleet.frontend("u1")
    dep = fe.deploy_code("my_mean", MEAN_X2)
    evs = list(dep.events())
    assert isinstance(dep, Deployment)
    deploys = [e for e in evs if isinstance(e, DeployEvent)]
    assert len(deploys) == 1
    assert deploys[0].md5 == dep.md5
    assert deploys[0].version == dep.version == 1
    assert deploys[0].n_installed == deploys[0].n_targets == 4
    assert isinstance(evs[-1], DoneEvent) and evs[-1].status == Status.DONE


def test_rollback_restores_prior_version_on_all_clients(fleet):
    fe = fleet.frontend("u1")
    v1 = fe.deploy_code("my_mean", MEAN_X2)
    v1.result()
    v2 = fe.deploy_code("my_mean", MEAN_X4)
    v2.result()
    assert (v1.version, v2.version) == (1, 2)
    for app in fleet.client_apps.values():
        assert app.registry.active_hash("u1", "my_mean") == v2.md5

    rb = v2.rollback()
    _, done = rb.result()
    assert done.status == Status.DONE
    assert rb.version == 1 and rb.md5 == v1.md5
    for app in fleet.client_apps.values():
        assert app.registry.active_hash("u1", "my_mean") == v1.md5

    # analytics now run the rolled-back version
    results, _ = fe.submit_analytics("my_mean",
                                     params={"n_values": 16}).result()
    assert results[0].winning_md5 == v1.md5


def test_rollback_without_prior_version_raises(fleet):
    fe = fleet.frontend("u1")
    dep = fe.deploy_code("my_mean", MEAN_X2)
    dep.result()
    with pytest.raises(ValueError, match="older than"):
        dep.rollback()


def test_rollback_reverts_mid_assignment_deploy_before_next_iteration():
    """The acceptance scenario: v1 is live, a long assignment starts, v2
    is deployed mid-assignment and then rolled back — later iterations
    are back on v1, all without restarting the assignment."""
    f = Fleet.create(4, seed=3)
    try:
        fe = f.frontend("u1")
        v1 = fe.deploy_code("my_mean", MEAN_X2)
        v1.result()

        handle = fe.submit_analytics("my_mean", iterations=8,
                                     params={"n_values": 16})
        stream = handle.events()
        first = next(stream)
        assert first.winning_md5 == v1.md5

        v2 = fe.deploy_code("my_mean", MEAN_X4)
        v2.result()
        rb = v2.rollback()
        _, done = rb.result()
        assert done.status == Status.DONE and rb.md5 == v1.md5

        results, done = handle.result(timeout=30.0)
        assert done.status == Status.DONE
        # the final iterations (after the rollback ack) ran v1 again,
        # with the whole fleet back in agreement
        assert results[-1].winning_md5 == v1.md5
        assert results[-1].n_dropped == 0
        # the paper's invariant: no *committed* iteration mixes
        # versions. While an install is still propagating client by
        # client, the majority filter enforces that by dropping the
        # minority side of the swap — so a committed winner is always
        # one of the two known versions, never a mixture, and the
        # steady-state iteration before the deploy dropped nobody
        assert results[0].n_dropped == 0
        assert all(r.winning_md5 in (v1.md5, v2.md5) for r in results)
        assert all(r.n_accepted + r.n_dropped + r.n_stragglers == 4
                   for r in results)
    finally:
        f.shutdown()


# ---------------------------------------------------------------------------
# Backpressure
# ---------------------------------------------------------------------------


def test_max_concurrent_assignments_backpressure():
    """With the gate at 1, three submissions still all complete — two
    queue inside the cloud node and are admitted FIFO."""
    f = Fleet.create(4, seed=0, max_concurrent_assignments=1)
    try:
        fe = f.frontend("u1")
        handles = [fe.submit_analytics("mean", iterations=2,
                                       params={"n_values": 8})
                   for _ in range(3)]
        for h in handles:
            results, done = h.result(timeout=30.0)
            assert done.status == Status.DONE
            assert len(results) == 2
    finally:
        f.shutdown()


def test_cancel_while_queued_behind_backpressure_gate():
    f = Fleet.create(4, seed=0, max_concurrent_assignments=1)
    try:
        fe = f.frontend("u1")
        running = fe.submit_analytics("mean", iterations=3,
                                      params={"n_values": 8})
        queued = fe.submit_analytics("mean", iterations=3,
                                     params={"n_values": 8})
        queued.cancel()
        results, done = queued.result(timeout=10.0)
        assert done.status == Status.CANCELLED
        assert results == []
        _, done = running.result(timeout=30.0)
        assert done.status == Status.DONE
    finally:
        f.shutdown()


# ---------------------------------------------------------------------------
# Registry-local deployments (train/serve path)
# ---------------------------------------------------------------------------


def test_local_deployment_versioning_and_rollback():
    reg = ActiveCodeRegistry()
    binding = reg.bind("u", "m")
    d1 = binding.deploy(MEAN_X2)
    d2 = binding.deploy(MEAN_X4)
    assert (d1.version, d2.version) == (1, 2)
    assert reg.active_hash("u", "m") == d2.md5
    back = d2.rollback()
    assert back.version == 1 and back.md5 == d1.md5
    assert reg.active_hash("u", "m") == d1.md5
    with pytest.raises(ValueError, match="older than"):
        back.rollback()
