"""Checkpoint store: atomic commit, integrity, retention, resume,
preemption save, elastic reshard-on-load."""
import dataclasses
import json
import os
import shutil
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import CheckpointStore, restore_tree, save_tree
from repro.configs import make_run_config
from repro.core.registry import ActiveCodeRegistry
from repro.data.synthetic import make_task
from repro.models import build_model
from repro.optim.api import build_optimizer
from repro.train import HotSwapTrainStep, TrainLoop, init_state


def tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16),
                  "d": jnp.zeros((), jnp.int32)}}


def test_roundtrip_bit_exact(tmp_path):
    t = tree()
    path = save_tree(str(tmp_path), t, step=3)
    got = restore_tree(path, t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_corruption_detected(tmp_path):
    t = tree()
    path = save_tree(str(tmp_path), t, step=1)
    leaf = os.path.join(path, "leaf_00000.npy")
    raw = bytearray(open(leaf, "rb").read())
    raw[-1] ^= 0xFF
    open(leaf, "wb").write(bytes(raw))
    with pytest.raises(IOError, match="corruption"):
        restore_tree(path, t)


def test_structure_mismatch_rejected(tmp_path):
    t = tree()
    path = save_tree(str(tmp_path), t, step=1)
    with pytest.raises(ValueError):
        restore_tree(path, {"a": t["a"]})


def test_tmp_dirs_ignored(tmp_path):
    store = CheckpointStore(str(tmp_path))
    store.save(tree(), step=1)
    os.makedirs(os.path.join(str(tmp_path), "step_00000002.tmp-abc"))
    assert store.latest().endswith("step_00000001")


def test_retention(tmp_path):
    store = CheckpointStore(str(tmp_path), keep=2)
    for s in range(5):
        store.save(tree(), step=s)
    steps = [s for s, _ in store.steps()]
    assert steps == [3, 4]


def test_async_save(tmp_path):
    store = CheckpointStore(str(tmp_path), blocking=False)
    store.save(tree(), step=9)
    deadline = time.time() + 5
    while time.time() < deadline and store.latest() is None:
        time.sleep(0.05)
    assert store.latest() is not None
    got, step = store.restore_latest(tree())
    assert step == 9


def _training(run, tmp, reg=None):
    model = build_model(run.model)
    opt = build_optimizer(run.train, run.model.param_dtype)
    state = init_state(model, opt, jax.random.PRNGKey(0), run)
    reg = reg or ActiveCodeRegistry()
    bindings = {s: reg.bind("u", s)
                for s in ("train_loss", "train_metrics", "grad_transform")}
    step = HotSwapTrainStep(model, run, opt, bindings)
    task = make_task(run.model.vocab_size, run.shape.seq_len,
                     run.shape.global_batch, seed=0)
    store = CheckpointStore(tmp)
    return state, TrainLoop(step, task, run, store=store, ckpt_every=5), \
        store


def small_run():
    run = make_run_config("smollm-135m", "train_4k")
    return dataclasses.replace(
        run, model=run.model.reduced(),
        shape=dataclasses.replace(run.shape, seq_len=32, global_batch=4),
        train=dataclasses.replace(run.train, learning_rate=1e-3,
                                  warmup_steps=2, total_steps=50))


def test_restart_resumes_bit_exact(tmp_path):
    """Crash/restart: restore + the stateless data pipeline reproduce
    the uninterrupted run exactly."""
    run = small_run()
    # uninterrupted: 10 steps
    state, loop, _ = _training(run, str(tmp_path / "x"))
    final = loop.run(state, 10)
    ref_losses = [h["loss"] for h in loop.history]

    # interrupted at 5 (checkpoint), new process restores and continues
    state2, loop2, store2 = _training(run, str(tmp_path / "y"))
    mid = loop2.run(state2, 5)
    store2.save(mid, step=5)
    state3, loop3, store3 = _training(run, str(tmp_path / "y"))
    restored, at = store3.restore_latest(mid)
    assert at == 5
    resumed = loop3.run(restored, 5)
    res_losses = [h["loss"] for h in loop3.history]
    np.testing.assert_allclose(res_losses, ref_losses[5:], rtol=1e-6)
    for a, b in zip(jax.tree.leaves(final.params),
                    jax.tree.leaves(resumed.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), rtol=1e-6)


def test_preemption_save(tmp_path):
    import signal
    run = small_run()
    state, loop, store = _training(run, str(tmp_path))
    loop.install_sigterm_save()
    calls = {"n": 0}

    def on_step(i, m):
        calls["n"] += 1
        if i == 2:
            os.kill(os.getpid(), signal.SIGTERM)

    state = loop.run(state, 20, on_step=on_step)
    assert calls["n"] == 3                       # stopped after step 2
    tagged = [d for d in os.listdir(str(tmp_path)) if "preempt" in d]
    assert tagged, "preemption checkpoint written"


def test_manifest_contents(tmp_path):
    t = tree()
    path = save_tree(str(tmp_path), t, step=4,
                     extra_meta={"arch": "smollm-135m"})
    m = json.load(open(os.path.join(path, "manifest.json")))
    assert m["step"] == 4 and m["arch"] == "smollm-135m"
    assert m["n_leaves"] == 3
    assert all("md5" in l for l in m["leaves"])
