"""ActiveCodeRegistry: versioning, rollback, isolation, on-disk mirror."""
import os

import pytest

from repro.core.codec import md5_of, module_path
from repro.core.module import ActiveModule
from repro.core.registry import ActiveCodeRegistry, UnknownSlotError

V1 = "def run(xs):\n    return 1.0\n"
V2 = "def run(xs):\n    return 2.0\n"


def test_versions_monotonic():
    reg = ActiveCodeRegistry()
    m1 = reg.deploy("u", "slot", V1)
    m2 = reg.deploy("u", "slot", V2)
    assert (m1.version, m2.version) == (1, 2)
    assert reg.resolve("u", "slot").md5 == m2.md5


def test_epoch_bumps_on_deploy():
    reg = ActiveCodeRegistry()
    e0 = reg.epoch
    reg.deploy("u", "slot", V1)
    assert reg.epoch == e0 + 1


def test_rollback_reactivates_old_version():
    reg = ActiveCodeRegistry()
    m1 = reg.deploy("u", "slot", V1)
    reg.deploy("u", "slot", V2)
    reg.rollback("u", "slot", m1.md5)
    assert reg.resolve("u", "slot").md5 == m1.md5
    with pytest.raises(KeyError):
        reg.rollback("u", "slot", "deadbeef")


def test_per_user_isolation():
    """Paper: custom code is tied to a user ID — no interference."""
    reg = ActiveCodeRegistry()
    reg.deploy("alice", "slot", V1)
    reg.deploy("bob", "slot", V2)
    assert float(reg.resolve("alice", "slot").fn(None)) == 1.0
    assert float(reg.resolve("bob", "slot").fn(None)) == 2.0
    assert reg.resolve("carol", "slot") is None


def test_binding_default_and_update():
    reg = ActiveCodeRegistry()
    b = reg.bind("u", "slot", default=lambda xs: 0.0)
    assert b.current().is_default
    reg.deploy("u", "slot", V1)
    assert not b.current().is_default
    assert b.current().version == 1


def test_binding_without_default_raises():
    reg = ActiveCodeRegistry()
    with pytest.raises(UnknownSlotError):
        reg.bind("u", "nope").current()


def test_compiled_cache_by_hash():
    """Flip-flopping between two versions never re-execs (A/B testing)."""
    reg = ActiveCodeRegistry()
    m1 = reg.deploy("u", "slot", V1)
    m2 = reg.deploy("u", "slot", V2)
    r2a = reg.resolve("u", "slot")
    reg.rollback("u", "slot", m1.md5)
    reg.rollback("u", "slot", m2.md5)
    assert reg.resolve("u", "slot") is r2a  # same compiled object


def test_on_disk_mirror(tmp_path):
    """Paper: module re-materialized as a file at a predefined path
    tied to the user id."""
    reg = ActiveCodeRegistry(store_root=str(tmp_path))
    reg.deploy("u", "slot", V1)
    path = module_path(str(tmp_path), "u", "slot", md5_of(V1))
    assert os.path.exists(path)
    assert open(path).read() == V1


def test_install_from_wire_revalidates():
    sender = ActiveCodeRegistry()
    mod = sender.deploy("u", "slot", V1)
    wire = mod.to_wire()
    receiver = ActiveCodeRegistry()
    got = receiver.install(ActiveModule.from_wire(wire))
    assert got.md5 == mod.md5
    assert receiver.resolve("u", "slot").version == mod.version


def test_wire_tamper_detected():
    reg = ActiveCodeRegistry()
    mod = reg.deploy("u", "slot", V1)
    wire = mod.to_wire()
    wire["code_b64"] = wire["code_b64"][:-4] + "AAA="
    with pytest.raises(ValueError, match="md5 mismatch"):
        ActiveModule.from_wire(wire)


def test_install_rejects_tampered_module():
    """Defense in depth: even a module object whose source was swapped
    after hashing (bypassing the codec's own check) is rejected at
    install time — the receiving registry re-derives both hashes."""
    from repro.core.codec import sha256_of
    from repro.core.validation import ValidationError

    good = ActiveModule.create("u", "slot", V1, version=1)
    tampered = ActiveModule(
        slot=good.slot, user_id=good.user_id,
        source=V2,                       # swapped payload
        md5=good.md5, sha256=good.sha256,  # stale announced hashes
        version=good.version, created_at=good.created_at)
    receiver = ActiveCodeRegistry()
    with pytest.raises(ValidationError, match="integrity check failed"):
        receiver.install(tampered)
    assert receiver.resolve("u", "slot") is None  # nothing was stored

    # md5 forged to match, sha256 stale: the second hash still catches it
    forged = ActiveModule(
        slot=good.slot, user_id=good.user_id, source=V2,
        md5=md5_of(V2), sha256=sha256_of(V1),
        version=good.version, created_at=good.created_at)
    with pytest.raises(ValidationError, match="sha256 mismatch"):
        receiver.install(forged)
