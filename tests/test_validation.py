"""Front-end validation: the paper's static + dynamic checks."""
import jax
import jax.numpy as jnp
import pytest

from repro.core.validation import (
    SlotSpec,
    ValidationError,
    scalar_output,
    static_check,
    validate,
)

GOOD = """
import jax.numpy as jnp
def run(xs):
    return jnp.mean(xs) * 2.0
"""


def test_good_module_passes():
    fn = validate(GOOD)
    assert float(fn(jnp.arange(4.0))) == pytest.approx(3.0)


def test_syntax_error_rejected():
    with pytest.raises(ValidationError, match="syntax"):
        validate("def run(xs: return xs")


def test_missing_run_rejected():
    with pytest.raises(ValidationError, match="run"):
        validate("def main(xs):\n    return xs\n")


@pytest.mark.parametrize("source,frag", [
    ("import os\ndef run(x):\n    return x\n", "os"),
    ("import subprocess\ndef run(x):\n    return x\n", "subprocess"),
    ("from socket import socket\ndef run(x):\n    return x\n", "socket"),
    ("def run(x):\n    return eval('1+1')\n", "eval"),
    ("def run(x):\n    return open('/etc/passwd')\n", "open"),
    ("def run(x):\n    return x.__class__\n", "dunder"),
    ("def run(x):\n    return getattr(x, 'shape')\n", "getattr"),
])
def test_sandbox_violations(source, frag):
    violations = static_check(source)
    assert violations, source
    with pytest.raises(ValidationError):
        validate(source)


def test_oversized_module_rejected():
    big = "def run(x):\n    return x\n" + "# pad\n" * 40000
    assert any("bytes" in v for v in static_check(big))


def test_runtime_import_blocked_dynamically():
    """Even if the AST walk were bypassed, the restricted __import__
    hook blocks disallowed imports at execution time."""
    from repro.core.validation import compile_restricted
    sneaky = "def run(x):\n    import os\n    return x\n"
    fn = compile_restricted(sneaky)
    with pytest.raises(ImportError):
        fn(1)


# ---------------------------------------------------------------------------
# Dynamic stage: interface probes via eval_shape (no FLOPs spent)
# ---------------------------------------------------------------------------

def _scalar_slot():
    return SlotSpec(
        name="reduce",
        probe_args=lambda: (jax.ShapeDtypeStruct((16,), jnp.float32),),
        check_output=scalar_output,
    )


def test_probe_accepts_matching_interface():
    fn = validate(GOOD, _scalar_slot())
    assert callable(fn)


def test_probe_rejects_wrong_output_shape():
    bad = "import jax.numpy as jnp\ndef run(xs):\n    return xs * 2\n"
    with pytest.raises(ValidationError, match="scalar"):
        validate(bad, _scalar_slot())


def test_probe_rejects_wrong_arity():
    bad = "def run(xs, ys):\n    return 0.0\n"
    with pytest.raises(ValidationError, match="probe failed"):
        validate(bad, _scalar_slot())


def test_module_level_crash_is_validation_failure():
    with pytest.raises(ValidationError, match="execution failed"):
        validate("raise RuntimeError('boom')\ndef run(x):\n    return x\n")
