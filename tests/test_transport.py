"""The wire-transport fabric: in-proc hub, TCP frames, reconnect-on-drop,
and node routing (plain names local, '@'-addresses through the codec)."""
import queue
import time
from dataclasses import dataclass
from typing import Any, Dict

import pytest

from repro.core import codec
from repro.core.actors import Actor
from repro.core.fleet import Deadline
from repro.core.transport import (
    InProcHub,
    InProcTransport,
    Node,
    TcpTransport,
    TransportError,
    make_addr,
    split_addr,
)


# a registered message type private to this suite
@dataclass(frozen=True)
class Ping:
    n: int

    def to_wire_dict(self) -> Dict[str, Any]:
        return {"n": self.n}

    @staticmethod
    def from_wire_dict(d: Dict[str, Any]) -> "Ping":
        return Ping(int(d["n"]))


codec.register_message("test_ping", Ping)


class Collector(Actor):
    def __init__(self, name):
        super().__init__(name)
        self.got: "queue.Queue[Any]" = queue.Queue()

    def handle(self, sender, msg):
        self.got.put((sender, msg))


# ---------------------------------------------------------------------------
# Addresses
# ---------------------------------------------------------------------------


def test_address_split_and_make():
    assert split_addr("cloud") == ("cloud", None)
    assert split_addr("cloud@node1") == ("cloud", "node1")
    # actor names may contain dots; only the last @ splits
    assert split_addr("cloud.asg1@cloud") == ("cloud.asg1", "cloud")
    assert make_addr("a", "n") == "a@n"


# ---------------------------------------------------------------------------
# InProc hub
# ---------------------------------------------------------------------------


def test_inproc_hub_delivers_bytes():
    hub = InProcHub()
    got = []
    a = InProcTransport(hub)
    a.start("a", got.append)
    b = InProcTransport(hub)
    b.start("b", lambda d: None)
    b.send("a", b"hello")
    assert got == [b"hello"]


def test_inproc_unknown_node_recorded_not_raised():
    hub = InProcHub()
    t = InProcTransport(hub)
    t.start("a", lambda d: None)
    t.send("ghost", b"x")
    assert hub.dropped == [("ghost", b"x")]


def test_inproc_detach_on_close():
    hub = InProcHub()
    t = InProcTransport(hub)
    t.start("a", lambda d: None)
    t.close()
    t2 = InProcTransport(hub)
    t2.start("a", lambda d: None)   # name is free again


# ---------------------------------------------------------------------------
# TCP
# ---------------------------------------------------------------------------


@pytest.fixture()
def tcp_pair():
    got_a, got_b = queue.Queue(), queue.Queue()
    a, b = TcpTransport(), TcpTransport()
    a.start("a", got_a.put)
    b.start("b", got_b.put)
    a.add_peer("b", b.endpoint)
    b.add_peer("a", a.endpoint)
    yield a, b, got_a, got_b
    a.close()
    b.close()


def test_tcp_frames_both_ways(tcp_pair):
    a, b, got_a, got_b = tcp_pair
    a.send("b", b"from-a")
    b.send("a", b"from-b")
    assert got_b.get(timeout=5.0) == b"from-a"
    assert got_a.get(timeout=5.0) == b"from-b"


def test_tcp_many_frames_in_order(tcp_pair):
    a, b, _, got_b = tcp_pair
    payloads = [f"frame-{i}".encode() * (i + 1) for i in range(50)]
    for p in payloads:
        a.send("b", p)
    assert [got_b.get(timeout=5.0) for _ in payloads] == payloads


def test_tcp_reconnect_after_drop(tcp_pair):
    a, b, _, got_b = tcp_pair
    a.send("b", b"one")
    assert got_b.get(timeout=5.0) == b"one"
    a.drop_connections()               # the wire went away under us
    a.send("b", b"two")                # must redial transparently
    assert got_b.get(timeout=5.0) == b"two"


def test_tcp_unknown_peer_raises():
    t = TcpTransport()
    t.start("a", lambda d: None)
    try:
        with pytest.raises(TransportError, match="no endpoint"):
            t.send("ghost", b"x")
    finally:
        t.close()


def test_tcp_unreachable_peer_raises_after_retries():
    t = TcpTransport(reconnect_attempts=2, reconnect_delay_s=0.01)
    t.start("a", lambda d: None)
    t.add_peer("dead", "127.0.0.1:1")   # nothing listens on port 1
    try:
        with pytest.raises(TransportError, match="cannot connect"):
            t.send("dead", b"x")
    finally:
        t.close()


def test_tcp_reconnect_backoff_is_exponential_capped_and_jittered(
        monkeypatch):
    """Retry delays must double per attempt up to the cap, with jitter in
    the upper half of each window — not the old tight linear loop."""
    base, cap, attempts = 0.05, 0.4, 8
    t = TcpTransport(reconnect_attempts=attempts, reconnect_delay_s=base,
                     reconnect_max_delay_s=cap)
    t.start("a", lambda d: None)
    t.add_peer("dead", "127.0.0.1:1")
    sleeps = []
    monkeypatch.setattr("repro.core.transport.time.sleep", sleeps.append)
    try:
        with pytest.raises(TransportError, match="cannot connect"):
            t.send("dead", b"x")
    finally:
        t.close()
    # no sleep after the final failed attempt — it raises immediately
    assert len(sleeps) == attempts - 1
    for i, s in enumerate(sleeps):
        ceiling = min(cap, base * 2 ** i)
        assert 0.5 * ceiling <= s <= ceiling, (i, s)
    # the cap actually engages for late attempts
    assert all(s <= cap for s in sleeps)
    assert any(s > 0.5 * cap for s in sleeps[4:])


# ---------------------------------------------------------------------------
# Node routing
# ---------------------------------------------------------------------------


def test_node_routes_across_hub_through_codec():
    hub = InProcHub()
    n1 = Node("n1", InProcTransport(hub))
    n2 = Node("n2", InProcTransport(hub))
    try:
        sink = Collector("sink")
        n2.spawn(sink)
        n1.route("sink@n2", Ping(42), sender="someone")
        sender, msg = sink.got.get(timeout=5.0)
        assert msg == Ping(42)
        assert sender == "someone@n1"  # senders are qualified in transit
    finally:
        n1.close()
        n2.close()


def test_node_local_plain_name_stays_object_reference():
    hub = InProcHub()
    n = Node("n1", InProcTransport(hub))
    try:
        sink = Collector("sink")
        n.spawn(sink)
        marker = object()               # not serializable on purpose
        n.route("sink", (marker,))      # plain name: no codec involved
        _, msg = sink.got.get(timeout=5.0)
        assert msg[0] is marker
    finally:
        n.close()


def test_node_self_addressed_send_still_crosses_codec():
    """The loopback discipline: an '@'-qualified send to *this* node
    encodes and decodes — so an unserializable message fails loudly
    instead of riding an object reference."""
    hub = InProcHub()
    n = Node("n1", InProcTransport(hub))
    try:
        sink = Collector("sink")
        n.spawn(sink)
        orig = Deadline(3)
        n.route("sink@n1", orig)
        _, msg = sink.got.get(timeout=5.0)
        assert msg == orig
        assert msg is not orig           # a decoded copy, not a reference

        class Opaque:
            pass

        with pytest.raises(codec.UnregisteredMessageError):
            n.route("sink@n1", Opaque())
    finally:
        n.close()


def test_poisoned_frame_dead_lettered_connection_survives():
    """A frame that fails to decode must not kill the reader: it lands
    in dead letters and later frames on the same connection still flow."""
    got = queue.Queue()
    a, b = TcpTransport(), TcpTransport()
    n2 = Node("b", b)

    class Sink(Actor):
        def handle(self, sender, msg):
            got.put(msg)

    try:
        a.start("a", lambda d: None)
        a.add_peer("b", b.endpoint)
        n2.spawn(Sink("sink"))
        a.send("b", b"not json at all")                       # poisoned
        a.send("b", codec.envelope_to_wire("sink", None, Ping(9)))
        assert got.get(timeout=5.0) == Ping(9)                # still alive
        assert len(n2.system.dead_letters) == 1
        assert n2.system.dead_letters[0].msg == b"not json at all"
    finally:
        a.close()
        n2.close()


def test_node_remote_failure_lands_in_dead_letters():
    t = TcpTransport(reconnect_attempts=1, reconnect_delay_s=0.01)
    n = Node("n1", t)
    try:
        n.route("sink@nowhere", Ping(1), sender="me")
        # the send fails on the outbound writer thread, so the dead
        # letter lands asynchronously
        deadline = time.time() + 5.0
        while not n.system.dead_letters and time.time() < deadline:
            time.sleep(0.005)
        assert len(n.system.dead_letters) == 1
        assert n.system.dead_letters[0].msg == Ping(1)
    finally:
        n.close()


def test_actor_send_uses_node_routing():
    """Actor.send('name@node', ...) goes through the fabric without the
    actor knowing anything about transports."""
    hub = InProcHub()
    n1 = Node("n1", InProcTransport(hub))
    n2 = Node("n2", InProcTransport(hub))

    class Echo(Actor):
        def handle(self, sender, msg):
            self.send(sender, Ping(msg.n + 1))

    try:
        echo = Echo("echo")
        n2.spawn(echo)
        sink = Collector("sink")
        n1.spawn(sink)
        n1.route("echo@n2", Ping(1), sender="sink")
        sender, msg = sink.got.get(timeout=5.0)
        assert msg == Ping(2)
        assert sender == "echo@n2"
    finally:
        n1.close()
        n2.close()
