"""Client churn: heartbeats, eviction, permanent stragglers, and
idempotent re-registration with module catch-up — the behaviour the
platform needs for a fleet of reference vehicles that come and go."""
import time

import numpy as np
import pytest

from repro.core import Status
from repro.core.fleet import (
    ClientApp,
    ClientNode,
    Fleet,
)
from repro.core.registry import ActiveCodeRegistry
from repro.core.transport import InProcTransport, Node

V1 = """
import jax.numpy as jnp
def run(xs):
    return jnp.mean(xs) * 2.0
"""


def _wait(predicate, timeout=10.0, interval=0.01):
    deadline = time.time() + timeout
    while not predicate():
        if time.time() > deadline:
            return False
        time.sleep(interval)
    return True


def test_create_rejects_eviction_without_heartbeats():
    with pytest.raises(ValueError, match="heartbeat_interval_s"):
        Fleet.create(2, eviction_timeout_s=1.0)
    with pytest.raises(ValueError, match="heartbeat_interval_s"):
        Fleet.create(2, heartbeat_interval_s=2.0, eviction_timeout_s=1.0)


def test_killed_client_straggles_then_is_evicted_round_completes():
    """A client dying mid-assignment costs at most one deadline: the
    iteration it straggles commits anyway, eviction then marks it a
    permanent straggler, and later iterations neither target nor wait
    for it."""
    fleet = Fleet.create(4, shards=2, seed=3,
                         heartbeat_interval_s=0.05, eviction_timeout_s=0.3)
    try:
        fe = fleet.frontend("u1")
        v1 = fe.deploy_code("t_mean", V1)
        _, done = v1.result(timeout=30.0)
        assert done.status == Status.DONE and "4/4" in done.detail

        handle = fe.submit_analytics(
            "t_mean", iterations=8,
            params={"n_values": 16, "straggler_grace_s": 0.15})
        stream = handle.events()
        first = next(stream)
        assert first.n_accepted == 4

        # "kill the process": its node drops off the hub, so tasks to it
        # black-hole and its heartbeats stop
        fleet.client_nodes[0].close(2.0)

        results, done = handle.result(timeout=60.0)
        assert done.status == Status.DONE
        assert len(results) == 8                      # the round completed
        assert any(r.n_stragglers == 1 for r in results)   # pre-eviction
        assert results[-1].n_accepted == 3
        assert results[-1].n_stragglers == 0          # permanent straggler:
        assert results[-1].n_dropped == 0             # not even targeted
    finally:
        fleet.shutdown()


def test_eviction_after_missed_heartbeats_updates_shard_and_router():
    fleet = Fleet.create(4, shards=2, seed=5,
                         heartbeat_interval_s=0.05, eviction_timeout_s=0.3)
    try:
        victim = "c000"
        owner = next(c for c in fleet.shard_clouds
                     if victim in c.client_nodes)
        before = owner.n_clients
        fleet.client_nodes[0].close(2.0)              # heartbeats stop
        assert _wait(lambda: victim not in owner.client_nodes)
        assert owner.n_clients == before - 1
        assert _wait(lambda: fleet.server.n_clients == 3)
    finally:
        fleet.shutdown()


def test_reconnecting_client_catches_up_on_deployed_module():
    """A client that re-registers after a drop (same client_id, fresh
    process => empty registry) receives the currently deployed module in
    the RegisterAck and can serve the custom method immediately."""
    fleet = Fleet.create(4, shards=2, seed=7,
                         heartbeat_interval_s=0.05, eviction_timeout_s=0.3)
    rejoined = None
    try:
        fe = fleet.frontend("u1")
        v1 = fe.deploy_code("t_mean", V1)
        _, done = v1.result(timeout=30.0)
        assert done.status == Status.DONE

        # the "process" restarts: same client_id, brand-new node id and a
        # completely empty registry
        fleet.client_nodes[0].close(2.0)
        assert _wait(lambda: fleet.server.n_clients == 3)

        app = ClientApp("c000", data=np.ones(256),
                        registry=ActiveCodeRegistry())
        rejoined = Node("c000-reborn", InProcTransport(fleet.hub))
        actor = ClientNode("client.c000", app,
                           register_with=fleet.cloud_addr,
                           heartbeat_interval_s=0.05)
        rejoined.spawn(actor)

        assert _wait(lambda: fleet.server.n_clients == 4)
        assert _wait(
            lambda: app.registry.resolve("u1", "t_mean") is not None)
        got = app.registry.resolve("u1", "t_mean")
        assert got.md5 == v1.md5 and got.version == v1.version

        # and it serves tasks again, fleet-wide rounds are back to 4
        results, done = fe.submit_analytics(
            "t_mean", iterations=1,
            params={"n_values": 16}).result(timeout=30.0)
        assert done.status == Status.DONE
        assert results[0].n_accepted == 4
    finally:
        if rejoined is not None:
            rejoined.close(2.0)
        fleet.shutdown()


def test_fleet_wide_deploy_reaches_empty_shards_for_catchup():
    """A shard whose clients all departed still records a fleet-wide
    deployment (vacuous 0/0 install), so a client that later joins that
    shard catches up via RegisterAck."""
    fleet = Fleet.create(4, shards=2, seed=11,
                         heartbeat_interval_s=0.05, eviction_timeout_s=0.3)
    rejoined = None
    try:
        fe = fleet.frontend("u1")
        victim_shard = next(c for c in fleet.shard_clouds if c.client_nodes)
        victims = sorted(victim_shard.client_nodes)
        for cid in victims:
            fleet.client_nodes[int(cid[1:])].close(2.0)
        assert _wait(lambda: victim_shard.n_clients == 0)
        survivors = 4 - len(victims)

        v1 = fe.deploy_code("t_mean", V1)        # deploy into the hole
        _, done = v1.result(timeout=30.0)
        assert done.status == Status.DONE
        assert f"{survivors}/{survivors}" in done.detail

        # a client rejoins the emptied shard: catch-up must deliver v1
        cid = victims[0]
        app = ClientApp(cid, data=np.ones(64),
                        registry=ActiveCodeRegistry())
        rejoined = Node(f"{cid}-reborn", InProcTransport(fleet.hub))
        rejoined.spawn(ClientNode(f"client.{cid}", app,
                                  register_with=fleet.cloud_addr,
                                  heartbeat_interval_s=0.05))
        assert _wait(
            lambda: app.registry.resolve("u1", "t_mean") is not None)
        assert app.registry.resolve("u1", "t_mean").md5 == v1.md5
    finally:
        if rejoined is not None:
            rejoined.close(2.0)
        fleet.shutdown()


def test_heartbeat_from_unknown_client_triggers_reregistration():
    """A shard that gets a heartbeat from a client it does not know
    (evicted while the client was merely slow, or the shard restarted)
    answers Evicted, and the client heals itself by re-registering."""
    fleet = Fleet.create(2, seed=1, heartbeat_interval_s=0.05,
                         eviction_timeout_s=0.4)
    try:
        cloud = fleet.server
        assert _wait(lambda: cloud.n_clients == 2)
        # forge the failure mode: the cloud forgets c001 without the
        # client ever noticing (e.g. a cloud-side restart)
        cloud.client_nodes.pop("c001", None)
        cloud._last_seen.pop("c001", None)
        # the client's next heartbeat draws an Evicted -> it re-registers
        assert _wait(lambda: "c001" in cloud.client_nodes, timeout=5.0)
    finally:
        fleet.shutdown()


def test_unsharded_fleet_supports_churn_too():
    """Eviction + permanent-straggler handling is a CloudNode property,
    not a router property: a plain 1-cloud fleet behaves the same."""
    fleet = Fleet.create(3, seed=9, heartbeat_interval_s=0.05,
                         eviction_timeout_s=0.3)
    try:
        fe = fleet.frontend("u1")
        handle = fe.submit_analytics(
            "mean", iterations=6,
            params={"n_values": 16, "straggler_grace_s": 0.15})
        next(handle.events())
        fleet.client_nodes[-1].close(2.0)
        results, done = handle.result(timeout=60.0)
        assert done.status == Status.DONE and len(results) == 6
        assert results[-1].n_accepted == 2
        assert results[-1].n_stragglers == 0
        assert _wait(lambda: fleet.server.n_clients == 2)
    finally:
        fleet.shutdown()


@pytest.mark.slow
def test_tcp_sharded_churn_scenario():
    """The acceptance scenario over real processes: 2 shard processes x
    4 client processes, deploy -> iterate -> kill one client -> evict ->
    redeploy to survivors -> rollback."""
    from repro.launch.fleet_proc import run_smoke

    assert run_smoke(n_clients=4, iterations=3, shards=2, churn=True,
                     verbose=False) == 0
