"""Shape-aware sharding rules (single-process: uses an abstract mesh)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import make_run_config
from repro.sharding.auto import (
    logical_to_spec_shaped,
    run_rules,
    sanitize_spec,
)
from repro.sharding.specs import make_rules


@pytest.fixture()
def mesh():
    # abstract 16x16 mesh: no devices touched. The AbstractMesh
    # constructor changed across jax versions: >=0.5 takes
    # (axis_sizes, axis_names), 0.4.x takes a shape tuple of pairs.
    try:
        return jax.sharding.AbstractMesh((16, 16), ("data", "model"))
    except TypeError:
        return jax.sharding.AbstractMesh((("data", 16), ("model", 16)))


def rules():
    return make_rules(("data", "model"))


def test_divisible_dims_shard(mesh):
    spec = logical_to_spec_shaped(("vocab", "embed"), (163840, 7168),
                                  rules(), mesh)
    assert spec == P("model", "data")


def test_indivisible_dim_skipped(mesh):
    # yi-34b: 56 heads on a 16-way axis -> replicated
    spec = logical_to_spec_shaped(("embed", "heads", "head_dim"),
                                  (7168, 56, 128), rules(), mesh)
    assert spec == P("data")


def test_indivisible_dim_does_not_shadow_later_dim(mesh):
    """The decode-cache bug: kv_heads=8 must NOT consume the model axis
    it cannot use — kv_seq gets it."""
    r = run_rules(make_run_config("qwen3-0.6b", "decode_32k"))
    spec = logical_to_spec_shaped(
        ("layers", "batch", "kv_heads", "kv_seq", "head_dim"),
        (28, 128, 8, 32768, 128), r, mesh)
    assert spec[3] == "model"          # kv_seq sharded
    assert spec[2] is None             # kv_heads replicated


def test_tuple_axis_prefix(mesh):
    # batch 32 divides 16 but not 16*16 when 'pod' absent; with the
    # 2-axis mesh ('pod','data') rule keeps only 'data'
    spec = logical_to_spec_shaped(("batch", "seq"), (32, 4096),
                                  rules(), mesh)
    assert spec[0] == "data"


def test_batch_one_replicated(mesh):
    spec = logical_to_spec_shaped(("batch", "seq"), (1, 524288),
                                  rules(), mesh)
    assert spec == P()                 # nothing shardable on dim 0


def test_sanitize_spec_drops_uneven(mesh):
    assert sanitize_spec((50280, 64), P("model", None), mesh) == P()
    assert sanitize_spec((50304, 64), P("model", None), mesh) == \
        P("model")


def test_run_rules_decode_kv_seq():
    r = run_rules(make_run_config("qwen3-0.6b", "decode_32k"))
    assert r.get("kv_seq") == "model"
    r2 = run_rules(make_run_config("qwen3-0.6b", "train_4k"))
    assert r2.get("kv_seq") is None


def test_sp_rules():
    run = make_run_config("yi-34b", "train_4k")   # SP on by default
    r = run_rules(run)
    assert r.get("seq") == "model"


def test_optimized_preset():
    base = make_run_config("yi-34b", "train_4k")
    opt = make_run_config("yi-34b", "train_4k", preset="optimized")
    assert base.sharding.attn_impl == "blockwise"
    assert opt.sharding.attn_impl == "ctxpar"
    assert opt.train.zero1 and not opt.sharding.fsdp_params
    # archs without a tuned preset fall back to baseline knobs
    same = make_run_config("dbrx-132b", "train_4k", preset="optimized")
    assert same.sharding == make_run_config("dbrx-132b",
                                            "train_4k").sharding
