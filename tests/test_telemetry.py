"""The fabric observability plane: wire-propagated trace context,
causal span trees across a sharded deploy, exact metrics under the
deterministic fault harness, the flight-recorder ring bound, and the
telemetry-off zero-byte guarantee.
"""
import json
import time

import pytest

from fault_fabric import FaultPlan, FaultyTransport
from test_codec import _examples

from repro.core import codec, tracing
from repro.core.assignment import Status
from repro.core.fleet import Fleet
from repro.core.telemetry import FlightRecorder, NodeTelemetry
from repro.core.tracing import TraceContext, assemble_trace
from repro.core.transport import InProcHub, InProcTransport, Node

V1 = """
import jax.numpy as jnp
def run(xs):
    return jnp.mean(xs) * 2.0
"""

V2 = """
import jax.numpy as jnp
def run(xs):
    return jnp.mean(xs) * 4.0
"""

CTX = TraceContext("ab" * 8, "cd" * 8, "ef" * 8)


def _wrap(plan):
    return lambda inner: FaultyTransport(inner, plan)


# ---------------------------------------------------------------------------
# Trace context on the wire
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("tag", sorted(_examples()))
def test_trace_context_survives_codec_round_trip_for_every_tag(tag):
    msg = _examples()[tag]
    data = codec.envelope_to_wire("cloud", "sink@user", msg, trace=CTX)
    to, sender, back, trace = codec.envelope_from_wire_traced(data)
    assert (to, sender) == ("cloud", "sink@user")
    assert type(back) is type(msg)
    assert trace == CTX


@pytest.mark.parametrize("tag", sorted(_examples()))
def test_untraced_envelope_has_zero_trace_bytes(tag):
    """Telemetry-off envelopes are byte-identical to the pre-tracing
    wire format: no trace keys, no size delta."""
    msg = _examples()[tag]
    plain = codec.envelope_to_wire("cloud", "sink@user", msg)
    # no envelope-level trace keys (a telemetry_snapshot's *payload*
    # legitimately carries span dicts with their own trace ids)
    top = json.loads(plain.decode("utf-8"))
    assert "trace_id" not in top and "span_id" not in top
    traced = codec.envelope_to_wire("cloud", "sink@user", msg, trace=CTX)
    assert len(traced) > len(plain)
    # decoding a plain envelope through the traced path yields None ctx
    *_, trace = codec.envelope_from_wire_traced(plain)
    assert trace is None


def test_trace_without_parent_omits_the_field():
    ctx = TraceContext("11" * 8, "22" * 8)
    data = codec.envelope_to_wire("a", None, _examples()["deadline"],
                                  trace=ctx)
    assert b"parent_span_id" not in data
    *_, back = codec.envelope_from_wire_traced(data)
    assert back == ctx


# ---------------------------------------------------------------------------
# Causal span tree across a sharded deploy
# ---------------------------------------------------------------------------


def test_sharded_deploy_assembles_connected_span_tree():
    """In-proc k=2: a deploy's spans — pulled over the wire from every
    node — form one connected tree rooted at the user's deploy span,
    with every segment of deploy-to-effect present and non-zero."""
    fleet = Fleet.create(4, shards=2)
    try:
        fe = fleet.frontend("u1")
        dep = fe.deploy_code("traced_mean", V1)
        _, done = dep.result(timeout=30.0)
        assert done.status == Status.DONE
        # first_commit closes at the first analytics commit won by the
        # freshly deployed version
        h = fe.submit_analytics("traced_mean", iterations=1,
                                params={"n_values": 8})
        _, done = h.result(timeout=30.0)
        assert done.status == Status.DONE

        assert dep.trace_id is not None
        tree = dep.trace(timeout=15.0)
        assert tree.is_connected, tree.to_dict()
        assert tree.root is not None and tree.root.name == "deploy"
        assert tree.root.node == "user"

        segs = tree.segments()
        for name in ("deploy", "router_fanout", "shard_install",
                     "client_install", "first_commit"):
            assert name in segs, sorted(segs)
            assert segs[name]["total_us"] > 0.0, (name, segs[name])
        assert segs["router_fanout"]["count"] == 1
        assert segs["shard_install"]["count"] == 2          # one per shard
        assert segs["client_install"]["count"] == 4         # one per client
        # causal duration covers the whole deploy-to-effect window: it
        # must reach at least as far as the latest segment end
        assert tree.duration_us >= max(s["reach_us"] for s in segs.values())
        # every span but the root hangs off a parent in the same trace
        ids = {s.span_id for s in tree.spans}
        for s in tree.spans:
            if s is not tree.root:
                assert s.parent_span_id in ids
    finally:
        fleet.shutdown()


def test_assignment_trace_is_separate_from_deploy_trace():
    fleet = Fleet.create(2)
    try:
        fe = fleet.frontend("u1")
        dep = fe.deploy_code("sep_mean", V1)
        dep.result(timeout=30.0)
        h = fe.submit_analytics("sep_mean", iterations=1,
                                params={"n_values": 8})
        h.result(timeout=30.0)
        assert h.trace_id is not None
        assert h.trace_id != dep.trace_id
        tree = fleet.trace(h.trace_id, timeout=15.0)
        assert tree.is_connected
        assert tree.root.name == "assignment"
    finally:
        fleet.shutdown()


def test_assemble_trace_dedupes_re_pulled_spans():
    spans = [{"trace_id": "t1", "span_id": "a", "parent_span_id": None,
              "name": "deploy", "node": "user",
              "start_ts": 1.0, "end_ts": 2.0}]
    tree = assemble_trace(spans + spans + [
        {"trace_id": "other", "span_id": "x", "parent_span_id": None,
         "name": "noise", "node": "user", "start_ts": 0.0, "end_ts": 9.0}],
        "t1")
    assert len(tree.spans) == 1
    assert tree.is_connected


# ---------------------------------------------------------------------------
# Metrics under the deterministic fault harness
# ---------------------------------------------------------------------------


def test_metrics_match_exact_counts_and_fault_deltas():
    """msgs_out counts route attempts (pre-fault), msgs_in counts real
    deliveries (post-fault): drops and duplicates show up as exact
    deltas between the two, matching the plan's own decision log."""
    plan = FaultPlan()
    plan.drop(tag="deadline", times=2)
    hub = InProcHub()
    node_a = Node("a", FaultyTransport(InProcTransport(hub), plan),
                  telemetry=NodeTelemetry("a"))
    node_b = Node("b", FaultyTransport(InProcTransport(hub), plan),
                  telemetry=NodeTelemetry("b"))

    from repro.core.actors import Actor

    class Sink(Actor):
        def __init__(self):
            super().__init__("sink")
            self.got = 0

        def handle(self, sender, msg):
            self.got += 1

    sink = node_b.spawn(Sink())
    from repro.core.fleet import Deadline
    for i in range(5):
        node_a.route("sink@b", Deadline(i))
    assert _wait(lambda: sink.got == 3)

    a, b = node_a.telemetry.metrics, node_b.telemetry.metrics
    assert a.counter("msgs_out.deadline") == 5
    assert b.counter("msgs_in.deadline") == 3
    assert plan.count(tag="deadline", action="drop") == 2
    assert plan.count(tag="deadline", action="deliver") == 3
    # the rule's own fired count agrees with the metric delta
    report = plan.report()
    (rule,) = report["rules"]
    assert rule["action"] == "drop" and rule["fired"] == 2
    assert rule["times_left"] == 0
    delta = a.counter("msgs_out.deadline") - b.counter("msgs_in.deadline")
    assert delta == rule["fired"]

    node_a.close()
    node_b.close()


def test_duplicates_visible_as_positive_delta():
    plan = FaultPlan()
    plan.duplicate(tag="deadline", times=3, copies=2)
    hub = InProcHub()
    node_a = Node("a", FaultyTransport(InProcTransport(hub), plan),
                  telemetry=NodeTelemetry("a"))
    node_b = Node("b", FaultyTransport(InProcTransport(hub), plan),
                  telemetry=NodeTelemetry("b"))

    from repro.core.actors import Actor

    class Sink(Actor):
        def __init__(self):
            super().__init__("sink")
            self.got = 0

        def handle(self, sender, msg):
            self.got += 1

    sink = node_b.spawn(Sink())
    from repro.core.fleet import Deadline
    for i in range(4):
        node_a.route("sink@b", Deadline(i))
    # 3 duplicated frames deliver 3 copies each, the 4th is clean
    assert _wait(lambda: sink.got == 10)
    assert node_a.telemetry.metrics.counter("msgs_out.deadline") == 4
    assert node_b.telemetry.metrics.counter("msgs_in.deadline") == 10
    assert plan.report()["rules"][0]["fired"] == 3
    node_a.close()
    node_b.close()


def test_fleet_metrics_exact_counts_one_round():
    """One analytics round on a 3-client in-proc fleet: the fleet-wide
    counter tables account for every fabric message exactly."""
    fleet = Fleet.create(3)
    try:
        fe = fleet.frontend("u1")
        h = fe.submit_analytics("mean", iterations=2,
                                params={"n_values": 8})
        _, done = h.result(timeout=30.0)
        assert done.status == Status.DONE
        m = fleet.metrics(timeout=15.0)
        assert set(m) == {"user", "cloud", "c000", "c001", "c002"}
        assert m["user"]["msgs_out.submit_assignment"] == 1
        assert m["cloud"]["msgs_in.submit_assignment"] == 1
        # 2 iterations x 3 clients
        assert m["cloud"]["msgs_out.new_task"] == 6
        assert m["cloud"]["msgs_in.task_done"] == 6
        for cid in ("c000", "c001", "c002"):
            assert m[cid]["msgs_in.new_task"] == 2
            assert m[cid]["msgs_out.task_done"] == 2
        # sent == received, tag by tag, across the whole fleet (loss-free
        # fabric; the in-flight snapshot replies are the one exception)
        sent: dict = {}
        recv: dict = {}
        for table in m.values():
            for k, v in table.items():
                if k.startswith("msgs_out."):
                    tag = k.removeprefix("msgs_out.")
                    sent[tag] = sent.get(tag, 0) + v
                elif k.startswith("msgs_in."):
                    tag = k.removeprefix("msgs_in.")
                    recv[tag] = recv.get(tag, 0) + v
        for tag, n in sent.items():
            # the pull's own messages are mid-flight while the snapshots
            # are being taken, so their counters are legitimately skewed
            if tag in ("telemetry_pull", "telemetry_snapshot"):
                continue
            assert recv.get(tag, 0) == n, (tag, sent, recv)
    finally:
        fleet.shutdown()


def test_fault_report_wired_into_flight_recorder_dump():
    """Fleet.create wires a FaultyTransport's plan.report() into every
    node's telemetry, so a post-mortem dump shows the injected faults."""
    plan = FaultPlan()
    plan.drop(tag="heartbeat", times=1)
    fleet = Fleet.create(2, transport_wrap=_wrap(plan))
    try:
        tel = fleet.user_node.telemetry
        assert tel is not None
        assert tel.fault_report_provider is not None
        out = tel.dump("test-dump", stream=open("/dev/null", "w"))
        assert out["fault_report"]["rules"][0]["action"] == "drop"
        assert out["node_id"] == "user"
        assert out["flight_recorder"] is True
    finally:
        fleet.shutdown()


# ---------------------------------------------------------------------------
# Flight recorder
# ---------------------------------------------------------------------------


def test_flight_recorder_ring_bound_enforced():
    rec = FlightRecorder("n1", capacity=8)
    for i in range(20):
        rec.record("out", f"tag{i}", "peer", i)
    assert len(rec) == 8
    events = rec.events()
    assert [e["tag"] for e in events] == [f"tag{i}" for i in range(12, 20)]
    assert events[-1]["bytes"] == 19


def test_dead_letter_leaves_artifacts_and_logs_once(caplog):
    """The PR-5 blind spot: a message to an unknown target now bumps a
    counter, lands in the ring, and logs the (tag, target) pair exactly
    once — instead of vanishing."""
    import io
    import logging

    tel = NodeTelemetry("solo", dump_stream=io.StringIO())
    hub = InProcHub()
    node = Node("solo", InProcTransport(hub), telemetry=tel)
    from repro.core.fleet import Deadline
    with caplog.at_level(logging.WARNING, logger="repro.fabric"):
        for _ in range(3):
            node.route("nobody@solo", Deadline(1))
        assert _wait(lambda: tel.metrics.counter("dead_letters") == 3)
    once = [r for r in caplog.records if "dead letter" in r.message]
    assert len(once) == 1
    assert "deadline" in once[0].getMessage()
    assert "nobody" in once[0].getMessage()
    dead = [e for e in tel.recorder.events() if e["dir"] == "dead"]
    assert len(dead) == 3
    # the dump that fired is valid JSON on the configured stream
    dumped = tel._dump_stream.getvalue()
    assert json.loads(dumped.splitlines()[0])["reason"].startswith(
        "dead-letter:deadline")
    node.close()


# ---------------------------------------------------------------------------
# Telemetry off: zero tax
# ---------------------------------------------------------------------------


def test_telemetry_off_fleet_has_no_observability_state():
    fleet = Fleet.create(2, telemetry=False)
    try:
        assert fleet.user_node.telemetry is None
        assert fleet.cloud_node.telemetry is None
        for n in fleet.client_nodes:
            assert n.telemetry is None
        fe = fleet.frontend("u1")
        dep = fe.deploy_code("off_mean", V1)
        _, done = dep.result(timeout=30.0)
        assert done.status == Status.DONE
        # no trace was ever opened, nothing is pullable
        assert dep.trace_id is None
        with pytest.raises(RuntimeError):
            dep.trace()
        with pytest.raises(RuntimeError):
            fleet.pull_telemetry()
        # and no thread leaked a context
        assert tracing.current() is None
    finally:
        fleet.shutdown()


def test_telemetry_off_adds_zero_envelope_bytes():
    """Capture real frames from a telemetry-off fleet round: none carry
    trace keys, so the hot path pays zero extra bytes per envelope."""
    frames = []

    class Tap:
        def __init__(self, inner):
            self.inner = inner

        def start(self, node_id, deliver):
            self.inner.start(node_id, deliver)

        def send(self, dest_node, data):
            frames.append(data)
            self.inner.send(dest_node, data)

        @property
        def endpoint(self):
            return self.inner.endpoint

        def add_peer(self, node_id, endpoint):
            self.inner.add_peer(node_id, endpoint)

        def forget_peer(self, node_id):
            self.inner.forget_peer(node_id)

        def close(self):
            self.inner.close()

        @property
        def on_peer_lost(self):
            return self.inner.on_peer_lost

        @on_peer_lost.setter
        def on_peer_lost(self, cb):
            self.inner.on_peer_lost = cb

    fleet = Fleet.create(2, telemetry=False, transport_wrap=Tap)
    try:
        fe = fleet.frontend("u1")
        h = fe.submit_analytics("mean", iterations=1,
                                params={"n_values": 8})
        _, done = h.result(timeout=30.0)
        assert done.status == Status.DONE
    finally:
        fleet.shutdown()
    assert frames
    for data in frames:
        assert b'"trace_id"' not in data
        assert b'"span_id"' not in data


def _wait(predicate, timeout=10.0, interval=0.01):
    deadline = time.time() + timeout
    while not predicate():
        if time.time() > deadline:
            return False
        time.sleep(interval)
    return True
