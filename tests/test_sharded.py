"""Sharded CloudNode behind a RouterNode: consistent-hash partitioning,
fan-out/fan-in through per-assignment aggregators, and the invariant the
whole design hangs on — the AssignmentHandle control-plane API is
byte-for-byte identical to the unsharded topology."""
import pytest

from repro.core import Status
from repro.core.assignment import Target
from repro.core.fleet import Fleet, ShardRing

V1 = """
import jax.numpy as jnp
def run(xs):
    return jnp.mean(xs) * 2.0
"""

V2 = """
import jax.numpy as jnp
def run(xs):
    return jnp.mean(xs) * 4.0
"""

AGG = """
import jax.numpy as jnp
def run(xs):
    return jnp.max(xs) - jnp.min(xs)
"""


# ---------------------------------------------------------------------------
# Consistent-hash ring
# ---------------------------------------------------------------------------


def test_ring_lookup_is_deterministic():
    a = ShardRing(["shard0", "shard1", "shard2"])
    b = ShardRing(["shard2", "shard0", "shard1"])   # insertion order irrelevant
    for i in range(200):
        cid = f"c{i:03d}"
        assert a.lookup(cid) == b.lookup(cid)


def test_ring_uses_every_shard():
    ring = ShardRing([f"shard{j}" for j in range(4)])
    owners = {ring.lookup(f"c{i:03d}") for i in range(200)}
    assert owners == {f"shard{j}" for j in range(4)}


def test_ring_resize_only_remaps_a_fraction():
    before = ShardRing(["shard0", "shard1", "shard2", "shard3"])
    after = ShardRing(["shard0", "shard1", "shard2"])   # shard3 removed
    clients = [f"c{i:03d}" for i in range(400)]
    moved = sum(1 for c in clients
                if before.lookup(c) != after.lookup(c)
                and before.lookup(c) != "shard3")
    # only clients shard3 owned should move; nobody else reshuffles
    assert moved == 0
    orphans = [c for c in clients if before.lookup(c) == "shard3"]
    assert orphans and all(after.lookup(c) in after.shard_ids
                           for c in orphans)


def test_ring_remove_and_empty():
    ring = ShardRing(["only"])
    assert ring.lookup("c000") == "only"
    ring.remove("only")
    assert ring.lookup("c000") is None


# ---------------------------------------------------------------------------
# Sharded fleet scenarios (in-proc topology; TCP is covered by the slow
# churn test and the CI smoke)
# ---------------------------------------------------------------------------


def test_sharded_full_scenario_handle_api_unchanged():
    """deploy -> iterate -> mid-assignment redeploy -> rollback on a
    2-shard fleet, asserting the same things the unsharded scenario
    asserts — no handle-API changes."""
    fleet = Fleet.create(4, shards=2, seed=11)
    assert fleet.shards == 2
    assert len(fleet.shard_nodes) == 2
    assert sum(c.n_clients for c in fleet.shard_clouds) == 4
    # shards own disjoint peer tables
    owned = [set(c.client_nodes) for c in fleet.shard_clouds]
    assert owned[0] & owned[1] == set()
    try:
        fe = fleet.frontend("u1")

        v1 = fe.deploy_code("t_mean", V1)
        _, done = v1.result(timeout=30.0)
        assert done.status == Status.DONE
        assert "4/4" in done.detail

        handle = fe.submit_analytics("t_mean", iterations=3,
                                     params={"n_values": 16})
        results, done = handle.result(timeout=30.0)
        assert done.status == Status.DONE
        assert [r.iteration for r in results] == [0, 1, 2]
        assert all(r.winning_md5 == v1.md5 for r in results)
        assert all(r.n_accepted == 4 for r in results)

        long = fe.submit_analytics("t_mean", iterations=8,
                                   params={"n_values": 16})
        stream = long.events()
        first = next(stream)
        assert first.winning_md5 == v1.md5
        v2 = fe.deploy_code("t_mean", V2)
        _, done = v2.result(timeout=30.0)
        assert done.status == Status.DONE

        rb = v2.rollback()
        _, done = rb.result(timeout=30.0)
        assert done.status == Status.DONE
        assert rb.md5 == v1.md5

        results, done = long.result(timeout=30.0)
        assert done.status == Status.DONE
        assert results[-1].winning_md5 == v1.md5
        # shards commit the same iteration number at independent times,
        # so during the swap one shard may commit on v1 while the other
        # is already on v2; the merge never mixes versions — dissenting
        # shards' results count as drops — and the per-iteration
        # accounting must still cover the whole fleet
        assert all(r.n_accepted + r.n_dropped + r.n_stragglers == 4
                   for r in results)
        assert all(r.winning_md5 in (v1.md5, v2.md5) for r in results)
    finally:
        fleet.shutdown()


def test_sharded_aggregation_runs_once_at_the_router():
    """cloud_method aggregation must merge across shards, not per shard:
    the fleet-wide mean over clients on different shards equals the mean
    over all accepted payloads."""
    fleet = Fleet.create(4, shards=2, seed=7)
    try:
        fe = fleet.frontend("u1")
        raw, done = fe.submit_analytics(
            "count", iterations=1,
            params={"n_values": 16}).result(timeout=30.0)
        assert done.status == Status.DONE
        assert sorted(raw[0].value) == [16, 16, 16, 16]  # concat, not nested

        agg, done = fe.submit_analytics(
            "count", iterations=1,
            params={"n_values": 16, "cloud_method": "mean"}
        ).result(timeout=30.0)
        assert done.status == Status.DONE
        assert agg[0].value == pytest.approx(16.0)
    finally:
        fleet.shutdown()


def test_sharded_cloud_target_deploy_installs_at_router():
    fleet = Fleet.create(4, shards=2, seed=3)
    try:
        fe = fleet.frontend("u1")
        dep = fe.deploy_code("spread", AGG, target=Target.CLOUD)
        _, done = dep.result(timeout=30.0)
        assert done.status == Status.DONE
        assert fleet.cloud_app.registry.resolve("u1", "spread") is not None
        # none of the shard registries got it — aggregation is router-only
        assert all(c.cloud_app.registry.resolve("u1", "spread") is None
                   for c in fleet.shard_clouds)

        res, done = fe.submit_analytics(
            "mean", iterations=1,
            params={"n_values": 16, "cloud_method": "spread"}
        ).result(timeout=30.0)
        assert done.status == Status.DONE
        assert isinstance(res[0].value, float)
    finally:
        fleet.shutdown()


def test_sharded_cancel_mid_assignment():
    fleet = Fleet.create(4, shards=2, seed=5)
    try:
        fe = fleet.frontend("u1")
        handle = fe.submit_analytics("mean", iterations=200,
                                     params={"n_values": 16})
        stream = handle.events()
        next(stream)                       # it is live on every shard
        handle.cancel()
        _, done = handle.result(timeout=30.0)
        assert done.status == Status.CANCELLED
    finally:
        fleet.shutdown()


def test_sharded_subset_targeting():
    """An assignment targeting two specific clients only reaches the
    shards that own them."""
    fleet = Fleet.create(6, shards=3, seed=9)
    try:
        fe = fleet.frontend("u1")
        results, done = fe.submit_analytics(
            "count", iterations=2, client_ids=["c000", "c003"],
            params={"n_values": 16}).result(timeout=30.0)
        assert done.status == Status.DONE
        assert all(r.n_accepted == 2 for r in results)
    finally:
        fleet.shutdown()


def test_sharded_no_clients_fails_cleanly():
    fleet = Fleet.create(2, shards=2, seed=1)
    try:
        fe = fleet.frontend("u1")
        _, done = fe.submit_analytics(
            "mean", iterations=1,
            client_ids=["nope"]).result(timeout=30.0)
        assert done.status == Status.FAILED
        assert "no clients" in done.detail
    finally:
        fleet.shutdown()
