"""Sharded CloudNode behind a RouterNode: consistent-hash partitioning,
fan-out/fan-in through per-assignment aggregators, the invariant the
whole design hangs on — the AssignmentHandle control-plane API is
byte-for-byte identical to the unsharded topology — and the exactness
of the cross-shard md5-majority: for ANY partition of tagged results
across shards, the sharded merge must equal ``majority_filter`` over
the flat result multiset (property-tested below; the hierarchical merge
this replaced provably diverges)."""
import pytest

from hyputil import require_hypothesis
from repro.core import Status
from repro.core.assignment import IterationEvent, Target
from repro.core.consistency import TaggedResult, majority_filter
from repro.core.fleet import (
    Fleet,
    ShardRing,
    merge_iteration_exact,
    merge_iteration_hierarchical,
    shard_hash_report,
)

V1 = """
import jax.numpy as jnp
def run(xs):
    return jnp.mean(xs) * 2.0
"""

V2 = """
import jax.numpy as jnp
def run(xs):
    return jnp.mean(xs) * 4.0
"""

AGG = """
import jax.numpy as jnp
def run(xs):
    return jnp.max(xs) - jnp.min(xs)
"""


# ---------------------------------------------------------------------------
# Consistent-hash ring
# ---------------------------------------------------------------------------


def test_ring_lookup_is_deterministic():
    a = ShardRing(["shard0", "shard1", "shard2"])
    b = ShardRing(["shard2", "shard0", "shard1"])   # insertion order irrelevant
    for i in range(200):
        cid = f"c{i:03d}"
        assert a.lookup(cid) == b.lookup(cid)


def test_ring_uses_every_shard():
    ring = ShardRing([f"shard{j}" for j in range(4)])
    owners = {ring.lookup(f"c{i:03d}") for i in range(200)}
    assert owners == {f"shard{j}" for j in range(4)}


def test_ring_resize_only_remaps_a_fraction():
    before = ShardRing(["shard0", "shard1", "shard2", "shard3"])
    after = ShardRing(["shard0", "shard1", "shard2"])   # shard3 removed
    clients = [f"c{i:03d}" for i in range(400)]
    moved = sum(1 for c in clients
                if before.lookup(c) != after.lookup(c)
                and before.lookup(c) != "shard3")
    # only clients shard3 owned should move; nobody else reshuffles
    assert moved == 0
    orphans = [c for c in clients if before.lookup(c) == "shard3"]
    assert orphans and all(after.lookup(c) in after.shard_ids
                           for c in orphans)


def test_ring_remove_and_empty():
    ring = ShardRing(["only"])
    assert ring.lookup("c000") == "only"
    ring.remove("only")
    assert ring.lookup("c000") is None


# ---------------------------------------------------------------------------
# Sharded fleet scenarios (in-proc topology; TCP is covered by the slow
# churn test and the CI smoke)
# ---------------------------------------------------------------------------


def test_sharded_full_scenario_handle_api_unchanged():
    """deploy -> iterate -> mid-assignment redeploy -> rollback on a
    2-shard fleet, asserting the same things the unsharded scenario
    asserts — no handle-API changes."""
    fleet = Fleet.create(4, shards=2, seed=11)
    assert fleet.shards == 2
    assert len(fleet.shard_nodes) == 2
    assert sum(c.n_clients for c in fleet.shard_clouds) == 4
    # shards own disjoint peer tables
    owned = [set(c.client_nodes) for c in fleet.shard_clouds]
    assert owned[0] & owned[1] == set()
    try:
        fe = fleet.frontend("u1")

        v1 = fe.deploy_code("t_mean", V1)
        _, done = v1.result(timeout=30.0)
        assert done.status == Status.DONE
        assert "4/4" in done.detail

        handle = fe.submit_analytics("t_mean", iterations=3,
                                     params={"n_values": 16})
        results, done = handle.result(timeout=30.0)
        assert done.status == Status.DONE
        assert [r.iteration for r in results] == [0, 1, 2]
        assert all(r.winning_md5 == v1.md5 for r in results)
        assert all(r.n_accepted == 4 for r in results)

        long = fe.submit_analytics("t_mean", iterations=8,
                                   params={"n_values": 16})
        stream = long.events()
        first = next(stream)
        assert first.winning_md5 == v1.md5
        v2 = fe.deploy_code("t_mean", V2)
        _, done = v2.result(timeout=30.0)
        assert done.status == Status.DONE

        rb = v2.rollback()
        _, done = rb.result(timeout=30.0)
        assert done.status == Status.DONE
        assert rb.md5 == v1.md5

        results, done = long.result(timeout=30.0)
        assert done.status == Status.DONE
        # shards commit the same iteration number at independent times,
        # so during the swap one shard may commit on v1 while the other
        # is already on v2; the merge never mixes versions — dissenting
        # shards' results count as drops — and the per-iteration
        # accounting must still cover the whole fleet
        assert all(r.n_accepted + r.n_dropped + r.n_stragglers == 4
                   for r in results)
        assert all(r.winning_md5 in (v1.md5, v2.md5) for r in results)

        # rollback took effect fleet-wide: deploys never block in-flight
        # rounds, so the long assignment's final round may legitimately
        # still commit v2 — but a round dispatched strictly after every
        # client acked the rollback install must commit v1
        post = fe.submit_analytics("t_mean", iterations=1,
                                   params={"n_values": 16})
        results, done = post.result(timeout=30.0)
        assert done.status == Status.DONE
        assert all(r.winning_md5 == v1.md5 for r in results)
    finally:
        fleet.shutdown()


def test_emission_window_paces_sharded_legs():
    """Sharded legs run under aggregator flow control: a leg may only
    start iterations inside its EmitWindow, and the aggregator re-arms
    every live leg as its merge frontier advances — so grants must flow
    router -> shard for an analytics assignment, while results still
    arrive complete, in order, and fully accounted."""
    from repro.core.fleet import EmitWindow

    # the grant survives the wire codec like any other fabric message
    w = EmitWindow("a1#2", 7)
    assert EmitWindow.from_wire_dict(w.to_wire_dict()) == w

    fleet = Fleet.create(4, shards=2, seed=7)
    try:
        fe = fleet.frontend("u1")
        handle = fe.submit_analytics("mean", iterations=6,
                                     params={"n_values": 8})
        results, done = handle.result(timeout=30.0)
        assert done.status == Status.DONE
        assert [r.iteration for r in results] == list(range(6))
        assert all(r.n_accepted + r.n_dropped + r.n_stragglers == 4
                   for r in results)
        m = fleet.metrics()
        granted = sum(t.get("msgs_in.emit_window", 0)
                      for node, t in m.items() if node.startswith("shard"))
        # 6 iterations across 2 legs, initial window 1: all but the very
        # first leg-local iteration waited on a grant
        assert granted > 0
        assert m["router"]["msgs_out.emit_window"] == granted
    finally:
        fleet.shutdown()


def test_sharded_aggregation_runs_once_at_the_router():
    """cloud_method aggregation must merge across shards, not per shard:
    the fleet-wide mean over clients on different shards equals the mean
    over all accepted payloads."""
    fleet = Fleet.create(4, shards=2, seed=7)
    try:
        fe = fleet.frontend("u1")
        raw, done = fe.submit_analytics(
            "count", iterations=1,
            params={"n_values": 16}).result(timeout=30.0)
        assert done.status == Status.DONE
        assert sorted(raw[0].value) == [16, 16, 16, 16]  # concat, not nested

        agg, done = fe.submit_analytics(
            "count", iterations=1,
            params={"n_values": 16, "cloud_method": "mean"}
        ).result(timeout=30.0)
        assert done.status == Status.DONE
        assert agg[0].value == pytest.approx(16.0)
    finally:
        fleet.shutdown()


def test_sharded_cloud_target_deploy_installs_at_router():
    fleet = Fleet.create(4, shards=2, seed=3)
    try:
        fe = fleet.frontend("u1")
        dep = fe.deploy_code("spread", AGG, target=Target.CLOUD)
        _, done = dep.result(timeout=30.0)
        assert done.status == Status.DONE
        assert fleet.cloud_app.registry.resolve("u1", "spread") is not None
        # none of the shard registries got it — aggregation is router-only
        assert all(c.cloud_app.registry.resolve("u1", "spread") is None
                   for c in fleet.shard_clouds)

        res, done = fe.submit_analytics(
            "mean", iterations=1,
            params={"n_values": 16, "cloud_method": "spread"}
        ).result(timeout=30.0)
        assert done.status == Status.DONE
        assert isinstance(res[0].value, float)
    finally:
        fleet.shutdown()


def test_sharded_cancel_mid_assignment():
    fleet = Fleet.create(4, shards=2, seed=5)
    try:
        fe = fleet.frontend("u1")
        handle = fe.submit_analytics("mean", iterations=200,
                                     params={"n_values": 16})
        stream = handle.events()
        next(stream)                       # it is live on every shard
        handle.cancel()
        _, done = handle.result(timeout=30.0)
        assert done.status == Status.CANCELLED
    finally:
        fleet.shutdown()


def test_sharded_subset_targeting():
    """An assignment targeting two specific clients only reaches the
    shards that own them."""
    fleet = Fleet.create(6, shards=3, seed=9)
    try:
        fe = fleet.frontend("u1")
        results, done = fe.submit_analytics(
            "count", iterations=2, client_ids=["c000", "c003"],
            params={"n_values": 16}).result(timeout=30.0)
        assert done.status == Status.DONE
        assert all(r.n_accepted == 2 for r in results)
    finally:
        fleet.shutdown()


def test_sharded_no_clients_fails_cleanly():
    fleet = Fleet.create(2, shards=2, seed=1)
    try:
        fe = fleet.frontend("u1")
        _, done = fe.submit_analytics(
            "mean", iterations=1,
            client_ids=["nope"]).result(timeout=30.0)
        assert done.status == Status.FAILED
        assert "no clients" in done.detail
    finally:
        fleet.shutdown()


# ---------------------------------------------------------------------------
# Exact cross-shard majority: the sharded merge as a pure function
# ---------------------------------------------------------------------------


def _shard_event(shard_results, iteration=0):
    """Build the shard-level IterationEvent the AssignmentHandler emits
    for one committed iteration: shard-local majority outcome plus the
    full per-md5 hash report."""
    outcome = majority_filter(shard_results)
    counts, payloads = shard_hash_report(shard_results)
    return IterationEvent(
        "asg-x#1", iteration, [r.payload for r in outcome.accepted],
        outcome.winning_md5, len(outcome.accepted), len(outcome.dropped), 0,
        hash_counts=counts, hash_payloads=payloads)


def _results(tagged):
    return [TaggedResult(f"c{i:03d}", 0, md5, payload=payload)
            for i, (md5, payload) in enumerate(tagged)]


def test_hierarchical_merge_loses_cross_shard_plurality_split():
    """The bug class the exact merge fixes, as a concrete counterexample:
    hash A holds the fleet-wide plurality (6 of 14) but is split 3/3
    across two shards, losing both shard-local votes 3-4 — so the
    hierarchical merge cannot even see A, while the exact merge commits
    it (and agrees with the flat filter)."""
    a, b, c = "aa" * 16, "bb" * 16, "cc" * 16
    shard1 = _results([(a, 1), (a, 2), (a, 3), (b, 10), (b, 11), (b, 12),
                       (b, 13)])
    shard2 = _results([(a, 4), (a, 5), (a, 6), (c, 20), (c, 21), (c, 22),
                       (c, 23)])
    flat = majority_filter(shard1 + shard2)
    assert flat.winning_md5 == a                  # ground truth: A wins

    events = [_shard_event(shard1), _shard_event(shard2)]
    h_winner, _, h_acc, _ = merge_iteration_hierarchical(events)
    assert h_winner != a                          # A is invisible to it
    assert h_winner == b                          # B/C tie, smaller md5

    winner, payloads, n_acc, n_drop = merge_iteration_exact(events)
    assert winner == a
    assert sorted(payloads) == [1, 2, 3, 4, 5, 6]
    assert n_acc == 6 and n_drop == 8


def test_exact_merge_single_shard_degenerates_to_local_filter():
    a, b = "aa" * 16, "bb" * 16
    shard = _results([(a, 1), (b, 2), (a, 3)])
    winner, payloads, n_acc, n_drop = merge_iteration_exact(
        [_shard_event(shard)])
    flat = majority_filter(shard)
    assert winner == flat.winning_md5
    assert payloads == [r.payload for r in flat.accepted]
    assert (n_acc, n_drop) == (len(flat.accepted), len(flat.dropped))


@pytest.mark.parametrize("seed", range(5))
def test_exact_merge_equals_flat_filter_random_partitions(seed):
    """Deterministic fuzz (seeded): random tagged results, random
    partition into up to 4 shards — the sharded aggregate must equal the
    flat majority_filter in winner, accepted multiset, and counts."""
    import random

    rng = random.Random(seed)
    hashes = ["aa" * 16, "bb" * 16, "cc" * 16, "dd" * 16]
    n = rng.randint(1, 40)
    flat = _results([(rng.choice(hashes), rng.randint(0, 99))
                     for _ in range(n)])
    k = rng.randint(1, 4)
    groups = [[] for _ in range(k)]
    for r in flat:
        groups[rng.randrange(k)].append(r)
    events = [_shard_event(g) for g in groups if g]

    winner, payloads, n_acc, n_drop = merge_iteration_exact(events)
    truth = majority_filter(flat)
    assert winner == truth.winning_md5
    assert sorted(payloads) == sorted(r.payload for r in truth.accepted)
    assert n_acc == len(truth.accepted)
    assert n_drop == len(truth.dropped)


def test_exact_merge_property_any_partition_equals_flat_filter():
    """The satellite property test proper: hypothesis searches the space
    of (result multiset, shard partition) for any case where the sharded
    merge diverges from consistency.majority_filter on the flat set."""
    hypothesis = require_hypothesis()
    st = pytest.importorskip("hypothesis.strategies")
    given, settings = hypothesis.given, hypothesis.settings

    tagged = st.lists(
        st.tuples(st.sampled_from(["aa" * 16, "bb" * 16, "cc" * 16]),
                  st.integers(min_value=0, max_value=9)),
        min_size=1, max_size=30)
    assignment = st.lists(st.integers(min_value=0, max_value=3),
                          min_size=30, max_size=30)

    @given(tagged=tagged, assignment=assignment)
    @settings(max_examples=200, deadline=None)
    def check(tagged, assignment):
        flat = _results(tagged)
        groups = {}
        for r, shard in zip(flat, assignment):
            groups.setdefault(shard, []).append(r)
        events = [_shard_event(g) for g in groups.values()]
        winner, payloads, n_acc, n_drop = merge_iteration_exact(events)
        truth = majority_filter(flat)
        assert winner == truth.winning_md5
        assert sorted(payloads) == sorted(r.payload for r in truth.accepted)
        assert n_acc == len(truth.accepted)
        assert n_drop == len(truth.dropped)

    check()
