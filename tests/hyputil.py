"""Shared gate for the hypothesis-based property suites.

Locally, hypothesis is optional: suites that need it skip with a clear
reason when the package is absent (the classic ``importorskip``).
In CI it is mandatory: the workflow sets ``REPRO_REQUIRE_HYPOTHESIS=1``
after installing the ``test`` extras, turning a missing install into a
hard failure instead of a silent skip — so the property suites can
never quietly drop out of the build again.
"""
import importlib
import os

import pytest


def require_hypothesis():
    """Import and return the ``hypothesis`` module, skipping the calling
    module when it is absent — unless REPRO_REQUIRE_HYPOTHESIS is set,
    in which case absence is a test failure (CI must run these)."""
    if os.environ.get("REPRO_REQUIRE_HYPOTHESIS"):
        return importlib.import_module("hypothesis")
    return pytest.importorskip(
        "hypothesis", reason="property tests need the hypothesis package")
